//! Bit-exact parity: `ParallelEngine` must reproduce `NativeEngine`
//! exactly — same candidate rows, same residuals, same marginals, to the
//! last bit — on every graph family and at every thread count.
//!
//! This is stronger than the float-tolerance parity the PJRT engine gets:
//! the parallel engine computes each row with the identical scalar op
//! sequence (shared with the native engine via
//! `engine::belief::candidate_row_from_belief`), so any drift is a bug.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::belief::BeliefCache;
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::util::Rng;
use bp_sched::Mrf;

fn test_graphs() -> Vec<(&'static str, Mrf)> {
    let mut rng = Rng::new(20_260_729);
    vec![
        (
            "ising8",
            DatasetSpec::Ising { n: 8, c: 2.5 }.generate(&mut rng).unwrap(),
        ),
        (
            "potts6_q5",
            DatasetSpec::Potts { n: 6, q: 5, c: 1.5 }.generate(&mut rng).unwrap(),
        ),
        ("protein", DatasetSpec::Protein.generate(&mut rng).unwrap()),
    ]
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "{what}[{i}]: native={x:?} parallel={y:?}"
        );
    }
}

/// Drive both engines through several rounds of compute-and-commit on
/// mixed frontiers, asserting bitwise equality at every step.
fn parity_run(label: &str, g: &Mrf, threads: usize) {
    let mut native = NativeEngine::new();
    let mut par = ParallelEngine::with_threads(threads);
    let a = g.max_arity;
    let mut logm = g.uniform_messages().as_slice().to_vec();
    let mut rng = Rng::new(7 + threads as u64);

    let full: Vec<i32> = (0..g.live_edges as i32).collect();
    let mut strided: Vec<i32> = (0..g.live_edges as i32).step_by(3).collect();
    rng.shuffle(&mut strided);
    let padded: Vec<i32> = vec![0, -1, 2, -1, (g.live_edges - 1) as i32];
    let frontiers = [&full, &strided, &padded, &full];

    for (round, frontier) in frontiers.iter().enumerate() {
        let nb = native.candidates(g, &logm, frontier).unwrap();
        let pb = par.candidates(g, &logm, frontier).unwrap();
        let what = format!("{label} t={threads} round{round}");
        assert_bits_equal(&nb.new_m, &pb.new_m, &format!("{what}.new_m"));
        assert_bits_equal(&nb.residuals, &pb.residuals, &format!("{what}.residuals"));
        // commit the native rows so later rounds compare at a
        // non-uniform message state
        for (i, &e) in frontier.iter().enumerate() {
            if e >= 0 {
                let e = e as usize;
                logm[e * a..(e + 1) * a].copy_from_slice(nb.row(i, a));
            }
        }
    }

    let nm = native.marginals(g, &logm).unwrap();
    let pm = par.marginals(g, &logm).unwrap();
    assert_bits_equal(&nm, &pm, &format!("{label} t={threads} marginals"));
}

#[test]
fn parity_single_thread() {
    for (label, g) in &test_graphs() {
        parity_run(label, g, 1);
    }
}

#[test]
fn parity_two_threads() {
    for (label, g) in &test_graphs() {
        parity_run(label, g, 2);
    }
}

#[test]
fn parity_eight_threads() {
    for (label, g) in &test_graphs() {
        parity_run(label, g, 8);
    }
}

#[test]
fn parallel_gather_bit_identical_at_every_thread_count() {
    // The chunk-parallel belief gather must fill the cache with exactly
    // the serial gather's bits on every graph family, at 1/2/4/8
    // threads — it is the drift guard's refresh path, so any divergence
    // would silently leak into tracked candidate evaluation.
    for (label, g) in &test_graphs() {
        let m = g.uniform_messages();
        let mut serial = BeliefCache::new();
        serial.gather(g, m.as_slice());
        for t in [1usize, 2, 4, 8] {
            let mut par = BeliefCache::new();
            par.gather_par(g, m.as_slice(), t);
            for v in 0..g.live_vertices {
                assert_bits_equal(
                    serial.row(v),
                    par.row(v),
                    &format!("{label} t={t} vertex {v}"),
                );
            }
        }
    }
}

#[test]
fn tracked_cache_parity_on_narrow_frontiers() {
    // Incremental maintenance with frontiers smaller than the vertex
    // count: every engine (native, parallel at 1/2/4/8 threads)
    // consumes the delta-maintained cache and must produce identical
    // bits round after round. Commits go through notify_commit exactly
    // as the coordinator would route them.
    for (label, g) in &test_graphs() {
        let a = g.max_arity;
        // frontier strictly smaller than the vertex count: the narrow
        // regime the incremental path exists for
        let k = (g.live_vertices / 2).max(1).min(g.live_edges);
        let frontier: Vec<i32> = (0..k as i32).collect();
        let mut engines: Vec<Box<dyn MessageEngine>> = vec![Box::new(NativeEngine::new())];
        for t in [1usize, 2, 4, 8] {
            engines.push(Box::new(ParallelEngine::with_threads(t)));
        }
        let mut logm = g.uniform_messages().as_slice().to_vec();
        for eng in engines.iter_mut() {
            eng.begin_tracking(g, &logm, 8);
        }
        for round in 0..6 {
            let mut batches = Vec::with_capacity(engines.len());
            for eng in engines.iter_mut() {
                batches.push(eng.candidates(g, &logm, &frontier).unwrap());
            }
            let base = &batches[0];
            for (i, b) in batches.iter().enumerate().skip(1) {
                let what = format!("{label} round{round} engine{i}");
                assert_bits_equal(&base.new_m, &b.new_m, &format!("{what}.new_m"));
                assert_bits_equal(&base.residuals, &b.residuals, &format!("{what}.residuals"));
            }
            // commit the wave through every engine's cache, then into logm
            for (i, &e) in frontier.iter().enumerate() {
                let e = e as usize;
                let row = base.row(i, a).to_vec();
                if logm[e * a..(e + 1) * a] != row[..] {
                    for eng in engines.iter_mut() {
                        eng.notify_commit(g, e, &logm[e * a..(e + 1) * a], &row);
                    }
                    logm[e * a..(e + 1) * a].copy_from_slice(&row);
                }
            }
        }
        for eng in engines.iter_mut() {
            eng.end_tracking();
        }
    }
}

#[test]
fn thread_counts_agree_with_each_other() {
    // Transitivity gives this from the parity tests, but assert it
    // directly: the parallel engine is deterministic across thread
    // counts, not just faithful to the native engine.
    let mut rng = Rng::new(31);
    let g = DatasetSpec::Ising { n: 10, c: 3.0 }.generate(&mut rng).unwrap();
    let logm = g.uniform_messages();
    let full: Vec<i32> = (0..g.live_edges as i32).collect();
    let base = ParallelEngine::with_threads(1)
        .candidates(&g, logm.as_slice(), &full)
        .unwrap();
    for t in [2, 3, 8] {
        let out = ParallelEngine::with_threads(t)
            .candidates(&g, logm.as_slice(), &full)
            .unwrap();
        assert_bits_equal(&base.new_m, &out.new_m, &format!("threads={t}"));
    }
}

/// Restores an env var's prior state on drop, so a failing assertion
/// cannot leak the override into other code in this process.
struct EnvGuard {
    key: &'static str,
    prev: Option<String>,
}

impl EnvGuard {
    fn set(key: &'static str, value: &str) -> EnvGuard {
        let prev = std::env::var(key).ok();
        std::env::set_var(key, value);
        EnvGuard { key, prev }
    }
}

impl Drop for EnvGuard {
    fn drop(&mut self) {
        match &self.prev {
            Some(v) => std::env::set_var(self.key, v),
            None => std::env::remove_var(self.key),
        }
    }
}

#[test]
fn determinism_under_env_thread_count() {
    // Two runs with BP_SCHED_THREADS=8 (the knob `ParallelEngine::new`
    // reads) produce identical marginals, bit for bit. (Env mutation is
    // process-global; the guard restores the prior value even on panic.
    // No other test in this binary reads the variable.)
    let _guard = EnvGuard::set("BP_SCHED_THREADS", "8");
    let run = || {
        let mut rng = Rng::new(42);
        let g = DatasetSpec::Ising { n: 8, c: 2.0 }.generate(&mut rng).unwrap();
        let mut eng = ParallelEngine::new();
        assert_eq!(eng.threads(), 8);
        let mut logm = g.uniform_messages().as_slice().to_vec();
        let a = g.max_arity;
        let full: Vec<i32> = (0..g.live_edges as i32).collect();
        for _ in 0..5 {
            let batch = eng.candidates(&g, &logm, &full).unwrap();
            for (i, &e) in full.iter().enumerate() {
                let e = e as usize;
                logm[e * a..(e + 1) * a].copy_from_slice(batch.row(i, a));
            }
        }
        eng.marginals(&g, &logm).unwrap()
    };
    let m1 = run();
    let m2 = run();
    assert_bits_equal(&m1, &m2, "marginals across identical runs");
}

#[test]
fn coordinator_runs_agree_between_engines() {
    // Full-stack check: Algorithm 1 with the parallel engine lands on
    // exactly the same iterate sequence as with the native engine.
    use bp_sched::coordinator::{run, RunParams};
    use bp_sched::sched::Lbp;
    let mut rng = Rng::new(55);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();
    let params = RunParams {
        want_marginals: true,
        timeout: 30.0,
        ..Default::default()
    };
    let rn = run(&g, &mut NativeEngine::new(), &mut Lbp::new(), &params).unwrap();
    let rp = run(
        &g,
        &mut ParallelEngine::with_threads(8),
        &mut Lbp::new(),
        &params,
    )
    .unwrap();
    assert_eq!(rn.iterations, rp.iterations);
    assert_eq!(rn.message_updates, rp.message_updates);
    assert_bits_equal(
        &rn.marginals.unwrap(),
        &rp.marginals.unwrap(),
        "coordinator marginals",
    );
}
