//! Differential harness: incremental belief maintenance vs full
//! re-gather, across every scheduler (lbp, rbp, srbp, rs, rnbp) on small
//! Ising/Potts/chain instances.
//!
//! The guard cadence `belief_refresh_every` (K) stratifies what can be
//! asserted:
//!
//! * **K=0** — tracking disabled: the gather-per-call contract, the
//!   differential *reference*.
//! * **K=1** — tracked (deltas applied, guard active), but any commit
//!   forces a full re-gather before the next read, so no candidate is
//!   ever computed from delta-maintained beliefs. Bit-identical to K=0
//!   *by construction*: identical frontiers, stop reasons, iterate
//!   counts, and bitwise marginals (hence trivially within 1e-5) —
//!   asserted for all five schedulers on every instance.
//! * **K=2 and K=64** (the default) — candidates really do read
//!   delta-drifted beliefs, so frontier equality with K=0 is no longer
//!   a theorem (a near-tied residual could sort differently). The
//!   asserts are the robust ones: both regimes converge, marginals
//!   agree at the fixed point, and the two incremental engines (native,
//!   parallel) remain *bitwise* identical to each other — the
//!   maintenance schedule, not the thread count, determines the bits.
//!
//! Plus: beliefs are bit-exact at every drift-guard refresh point, and
//! serial SRBP (no belief cache) is maintenance-invariant.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

use bp_sched::coordinator::{run, RunParams, RunResult, StopReason};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::belief::BeliefCache;
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::sched::{srbp, Lbp, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;

const GPU_SCHEDULERS: [&str; 4] = ["lbp", "rbp", "rs", "rnbp"];

fn test_graphs() -> Vec<(&'static str, Mrf)> {
    let mut rng = Rng::new(20_260_729);
    vec![
        (
            "ising6",
            DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap(),
        ),
        (
            "potts5_q3",
            DatasetSpec::Potts { n: 5, q: 3, c: 1.0 }.generate(&mut rng).unwrap(),
        ),
        (
            "chain40",
            DatasetSpec::Chain { n: 40, c: 5.0 }.generate(&mut rng).unwrap(),
        ),
    ]
}

fn mk_sched(name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(0.25)),
        "rs" => Box::new(ResidualSplash::new(0.25, 2)),
        "rnbp" => Box::new(Rnbp::synthetic(0.7, 11)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    match name {
        "native" => Box::new(NativeEngine::new()),
        "parallel" => Box::new(ParallelEngine::with_threads(4)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(refresh_every: usize) -> RunParams {
    RunParams {
        want_marginals: true,
        timeout: 30.0,
        belief_refresh_every: refresh_every,
        ..Default::default()
    }
}

fn run_one(g: &Mrf, sched: &str, engine: &str, refresh_every: usize) -> RunResult {
    let mut eng = mk_engine(engine);
    let mut s = mk_sched(sched);
    run(g, eng.as_mut(), s.as_mut(), &params(refresh_every)).unwrap()
}

fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}[{i}]: {x:?} vs {y:?}");
    }
}

/// Strict differential: same stop reason, same frontier trajectory,
/// same iterate counts, bitwise-identical marginals.
fn assert_trajectories_match(full: &RunResult, inc: &RunResult, what: &str) {
    assert_eq!(full.stop, inc.stop, "{what}: stop");
    assert_eq!(full.iterations, inc.iterations, "{what}: iterations");
    assert_eq!(
        full.message_updates, inc.message_updates,
        "{what}: message updates"
    );
    assert_eq!(
        full.frontier_digest, inc.frontier_digest,
        "{what}: frontier digests (the two regimes selected different frontiers)"
    );
    assert_bits_equal(
        full.marginals.as_ref().unwrap(),
        inc.marginals.as_ref().unwrap(),
        &format!("{what}: marginals"),
    );
}

#[test]
fn refresh_cadence_one_matches_full_gather_bitwise() {
    for (glabel, g) in &test_graphs() {
        for sched in GPU_SCHEDULERS {
            for engine in ["native", "parallel"] {
                let full = run_one(g, sched, engine, 0);
                let inc = run_one(g, sched, engine, 1);
                let what = format!("{glabel}/{sched}/{engine} K=1");
                assert_trajectories_match(&full, &inc, &what);
            }
        }
    }
}

#[test]
fn drift_cadences_converge_and_agree_at_fixed_point() {
    // K=2 and K=64 (the default): candidate evaluation genuinely
    // consumes delta-maintained beliefs (up to K-1 commits of ulp-scale
    // drift between guard refreshes). Frontier equality with the K=0
    // regime is no longer a structural theorem — a near-tied residual
    // could in principle sort differently — so the asserts here are the
    // robust ones: both regimes converge, they land on the same fixed
    // point, and the incremental regime itself is engine- and
    // thread-independent, bit for bit (the maintenance schedule, not
    // the executor, determines the bits).
    for (glabel, g) in &test_graphs() {
        for sched in GPU_SCHEDULERS {
            let full = run_one(g, sched, "native", 0);
            for k in [2usize, 64] {
                let inc_native = run_one(g, sched, "native", k);
                let inc_par = run_one(g, sched, "parallel", k);
                let what = format!("{glabel}/{sched} K={k}");
                assert_eq!(full.stop, StopReason::Converged, "{what}: full regime");
                assert_eq!(inc_native.stop, StopReason::Converged, "{what}: incremental");
                for (i, (x, y)) in full
                    .marginals
                    .as_ref()
                    .unwrap()
                    .iter()
                    .zip(inc_native.marginals.as_ref().unwrap())
                    .enumerate()
                {
                    assert!((x - y).abs() < 1e-3, "{what}: marginal[{i}] {x} vs {y}");
                }
                assert_eq!(
                    inc_native.frontier_digest, inc_par.frontier_digest,
                    "{what}: incremental engines diverged"
                );
                assert_eq!(inc_native.iterations, inc_par.iterations, "{what}");
                assert_bits_equal(
                    inc_native.marginals.as_ref().unwrap(),
                    inc_par.marginals.as_ref().unwrap(),
                    &format!("{what}: cross-engine incremental marginals"),
                );
            }
        }
    }
}

#[test]
fn srbp_is_maintenance_invariant() {
    // The serial baseline has no belief cache: the knob must not change
    // a single bit of its trajectory or result.
    let mut rng = Rng::new(99);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();
    let a = srbp::run_serial(&g, &params(0)).unwrap();
    let b = srbp::run_serial(&g, &params(64)).unwrap();
    assert_eq!(a.stop, b.stop);
    assert_eq!(a.message_updates, b.message_updates);
    assert_eq!(a.frontier_digest, b.frontier_digest);
    assert_bits_equal(
        a.marginals.as_ref().unwrap(),
        b.marginals.as_ref().unwrap(),
        "srbp marginals",
    );
}

#[test]
fn beliefs_bit_exact_at_every_refresh_point() {
    // Drive a tracked cache through random commits; at every guard
    // refresh the tracked beliefs must equal a from-scratch gather of
    // the current messages, bit for bit (a refresh *is* one, and must
    // leave no delta residue behind).
    let mut rng = Rng::new(4242);
    let g = DatasetSpec::Protein.generate(&mut rng).unwrap();
    let a = g.max_arity;
    let mut logm = g.uniform_messages().as_slice().to_vec();
    let mut cache = BeliefCache::new();
    cache.begin_tracking(&g, &logm, 8, 4);
    let mut fresh = BeliefCache::new();
    let mut row = vec![0.0f32; a];
    let mut refreshes = 0;
    for _ in 0..200 {
        let e = rng.below(g.live_edges);
        let av = g.arity_of(g.dst[e] as usize);
        for x in row[..av].iter_mut() {
            *x = rng.range(-3.0, 0.0) as f32;
        }
        for x in row[av..].iter_mut() {
            *x = 0.0;
        }
        cache.apply_commit(&g, e, &logm[e * a..(e + 1) * a], &row);
        logm[e * a..(e + 1) * a].copy_from_slice(&row);
        if cache.refresh_if_due(&g, &logm, 4) {
            refreshes += 1;
            fresh.gather(&g, &logm);
            for v in 0..g.live_vertices {
                assert_bits_equal(
                    cache.row(v),
                    fresh.row(v),
                    &format!("refresh {refreshes}, vertex {v}"),
                );
            }
        }
    }
    assert_eq!(refreshes, 200 / 8, "guard cadence");
}
