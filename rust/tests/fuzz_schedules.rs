//! Seeded randomized differential fuzzer over the scheduler × refresh
//! mode × engine matrix.
//!
//! Each root seed deterministically generates a batch of small random
//! MRFs (ising / potts / chain mix with randomized size, coupling, ε,
//! damping, scheduler parameters, and engine thread counts) and
//! cross-checks, for every GPU scheduler:
//!
//! * **lazy ≡ exact** — frontier digests, iteration counts, message
//!   updates, and bitwise marginals agree (the certified-boundary
//!   contract; lbp via the resolve-all default). The one tolerated
//!   asymmetry is the cap boundary: a run that exact declares
//!   `Converged` exactly at the iteration cap surfaces as
//!   `IterationCap` under lazy, with identical trajectories.
//! * **bounded ≡ exact for the strictly ε-filtered schedulers** (rbp,
//!   rnbp — the PR 3 theorem), and fixed-point tolerance for rs/lbp on
//!   converged runs.
//! * **native ≡ parallel** per mode (bit-identical engines), when the
//!   engine matrix is not pinned by `BP_TEST_ENGINE`.
//! * **Bound soundness** via the `RunObserver` seam on a sample of
//!   lazy runs: maintained upper bounds dominate a from-scratch
//!   recompute at every refresh point.
//! * **Stop honesty** — no run reports `Converged` while any true
//!   residual is hot (or NaN), and no built-in scheduler stalls.
//! * **estimate leg** (separate fn, same replayed case stream) —
//!   honesty, the no-refresh counter shape, fixed-point agreement with
//!   exact, cross-engine bit-identity, and the narrow-frontier row
//!   economy vs lazy under `--residual-refresh estimate`.
//! * **mq envelope** — the relaxed Multiqueue has no digest to compare
//!   (its waves depend on thread interleaving at >1 worker), so it gets
//!   envelope assertions instead: honesty on every run, fixed-point
//!   agreement with exact RBP on converged runs, a per-seed converged
//!   rate at least RBP's, and conserved per-worker commit accounting.
//!
//! Budgets are iteration-based (huge wallclock timeout, no cost model),
//! so every run is bit-deterministic for a given root seed.
//! `BP_FUZZ_SEED` pins one root seed (the CI matrix runs 11 / 22 / 33
//! in separate legs); unset, all three run.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

mod common;

use bp_sched::coordinator::campaign::EvidenceStream;
use bp_sched::coordinator::{
    run, run_observed, ResidualRefresh, RunParams, RunResult, SessionBuilder, StopReason,
};
use bp_sched::engine::{
    native::NativeEngine, parallel::ParallelEngine, MessageEngine, Semiring, UpdateOptions,
};
use bp_sched::sched::{srbp, Lbp, Multiqueue, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;
use common::{assert_bits_equal, engines_under_test, BoundAuditor};

const DEFAULT_ROOT_SEEDS: [u64; 3] = [11, 22, 33];
const CASES_PER_SEED: usize = 17;
const MODES: [ResidualRefresh; 3] = [
    ResidualRefresh::Exact,
    ResidualRefresh::Bounded,
    ResidualRefresh::Lazy,
];

fn root_seeds() -> Vec<u64> {
    match std::env::var("BP_FUZZ_SEED") {
        Ok(s) => vec![s.parse().expect("BP_FUZZ_SEED must be a u64")],
        Err(_) => DEFAULT_ROOT_SEEDS.to_vec(),
    }
}

/// One randomized scenario: graph + run knobs + scheduler parameters.
struct FuzzCase {
    label: String,
    graph: Mrf,
    eps: f32,
    damping: f32,
    engine_threads: usize,
    rbp_p: f64,
    rs_p: f64,
    rs_h: usize,
    rnbp_low: f64,
    rnbp_high: f64,
    rnbp_seed: u64,
}

fn gen_case(rng: &mut Rng, id: usize) -> FuzzCase {
    // graph sampling shared with tests/session_warm_start.rs — the draw
    // sequence is part of each seed's reproducible case stream
    let (glabel, graph) = common::random_mrf(rng);
    let eps = [1e-3f32, 5e-4, 1e-4][rng.below(3)];
    let damping = [0.0f32, 0.0, 0.3][rng.below(3)];
    let engine_threads = [1usize, 2, 4][rng.below(3)];
    FuzzCase {
        label: format!("case{id}:{glabel}/eps{eps}/damp{damping}/t{engine_threads}"),
        graph,
        eps,
        damping,
        engine_threads,
        rbp_p: [1.0 / 16.0, 0.25, 1.0][rng.below(3)],
        rs_p: [1.0 / 16.0, 0.25][rng.below(2)],
        rs_h: 1 + rng.below(2),
        rnbp_low: [0.3, 0.7][rng.below(2)],
        rnbp_high: [0.9, 1.0][rng.below(2)],
        rnbp_seed: rng.next_u64(),
    }
}

fn mk_sched(case: &FuzzCase, name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(case.rbp_p)),
        "rs" => Box::new(ResidualSplash::new(case.rs_p, case.rs_h)),
        "rnbp" => Box::new(Rnbp::new(case.rnbp_low, case.rnbp_high, case.rnbp_seed)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(case: &FuzzCase, name: &str) -> Box<dyn MessageEngine> {
    let opts = UpdateOptions {
        semiring: Semiring::SumProduct,
        damping: case.damping,
    };
    match name {
        "native" => Box::new(NativeEngine::with_options(opts)),
        "parallel" => Box::new(ParallelEngine::with_options_threads(opts, case.engine_threads)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(case: &FuzzCase, mode: ResidualRefresh) -> RunParams {
    RunParams {
        eps: case.eps,
        // deterministic stop: iteration budget only — wallclock and
        // simulated clocks must never race the differential
        max_iterations: 400,
        timeout: 1e9,
        cost_model: None,
        want_marginals: true,
        belief_refresh_every: 0,
        residual_refresh: mode,
        ..Default::default()
    }
}

fn run_one(case: &FuzzCase, sched: &str, engine: &str, mode: ResidualRefresh) -> RunResult {
    let mut eng = mk_engine(case, engine);
    let mut s = mk_sched(case, sched);
    run(&case.graph, eng.as_mut(), s.as_mut(), &params(case, mode)).unwrap()
}

/// Stop honesty: `Converged` must mean every residual upper bound (and
/// so every true residual) is below eps — NaN counts as hot — and no
/// built-in scheduler may stall on these poison-free runs.
fn assert_honest_eps(r: &RunResult, eps: f32, what: &str) {
    assert_ne!(
        r.stop,
        StopReason::Stalled,
        "{what}: built-in scheduler stalled"
    );
    if r.stop == StopReason::Converged {
        assert!(
            !r.final_residual.is_nan() && r.final_residual < eps,
            "{what}: Converged with hot/NaN final residual {} (eps {eps})",
            r.final_residual
        );
    }
}

/// lazy vs exact: identical trajectories, tolerating only the
/// cap-boundary stop asymmetry (identical messages either way).
fn assert_lazy_matches_exact(exact: &RunResult, lazy: &RunResult, what: &str) {
    match (exact.stop, lazy.stop) {
        (a, b) if a == b => {}
        (StopReason::Converged, StopReason::IterationCap) => {
            // exact certified convergence at the very loop head the cap
            // fires on; lazy still carried unresolved bounds there
        }
        other => panic!("{what}: stop mismatch {other:?}"),
    }
    assert_eq!(exact.iterations, lazy.iterations, "{what}: iterations");
    assert_eq!(
        exact.message_updates, lazy.message_updates,
        "{what}: message updates"
    );
    assert_eq!(
        exact.frontier_digest, lazy.frontier_digest,
        "{what}: frontier digests diverged"
    );
    assert_bits_equal(
        exact.marginals.as_ref().unwrap(),
        lazy.marginals.as_ref().unwrap(),
        &format!("{what}: marginals"),
    );
    assert_eq!(lazy.refresh_skipped, 0, "{what}: lazy must defer, not skip");
    assert!(
        lazy.refresh_resolved <= lazy.refresh_deferred,
        "{what}: resolved {} > deferred {}",
        lazy.refresh_resolved,
        lazy.refresh_deferred
    );
}

fn check_case(case: &FuzzCase) {
    let engines = engines_under_test();
    for sched in ["lbp", "rbp", "rs", "rnbp"] {
        // per engine: the three refresh modes
        let mut per_engine: Vec<[RunResult; 3]> = Vec::new();
        for &engine in &engines {
            let what = format!("{}/{sched}/{engine}", case.label);
            let exact = run_one(case, sched, engine, ResidualRefresh::Exact);
            let bounded = run_one(case, sched, engine, ResidualRefresh::Bounded);
            let lazy = run_one(case, sched, engine, ResidualRefresh::Lazy);
            for r in [&exact, &bounded, &lazy] {
                assert_honest_eps(r, case.eps, &what);
            }

            assert_lazy_matches_exact(&exact, &lazy, &what);

            if sched == "rbp" || sched == "rnbp" {
                // strictly ε-filtered: bounded is the PR 3 bit-identity
                assert_eq!(exact.stop, bounded.stop, "{what}: bounded stop");
                assert_eq!(
                    exact.frontier_digest, bounded.frontier_digest,
                    "{what}: bounded digest"
                );
                assert_eq!(bounded.refresh_skipped, 0, "{what}: deltas are >= eps");
                assert_bits_equal(
                    exact.marginals.as_ref().unwrap(),
                    bounded.marginals.as_ref().unwrap(),
                    &format!("{what}: bounded marginals"),
                );
            } else if exact.converged() && bounded.converged() {
                // sub-ε committers: fixed-point tolerance on converged runs
                for (i, (x, y)) in exact
                    .marginals
                    .as_ref()
                    .unwrap()
                    .iter()
                    .zip(bounded.marginals.as_ref().unwrap())
                    .enumerate()
                {
                    assert!(
                        (x - y).abs() < 1e-3,
                        "{what}: bounded marginal[{i}] {x} vs {y}"
                    );
                }
            }
            per_engine.push([exact, bounded, lazy]);
        }
        // cross-engine: native and parallel are bit-identical per mode
        if per_engine.len() == 2 {
            for (mi, mode) in MODES.iter().enumerate() {
                let (a, b) = (&per_engine[0][mi], &per_engine[1][mi]);
                let what = format!("{}/{sched}/{mode:?} native-vs-parallel", case.label);
                assert_eq!(a.stop, b.stop, "{what}");
                assert_eq!(a.frontier_digest, b.frontier_digest, "{what}");
                assert_bits_equal(
                    a.marginals.as_ref().unwrap(),
                    b.marginals.as_ref().unwrap(),
                    &what,
                );
            }
        }
    }

    // serial baseline: honesty only (no dirty-list refresh to fuzz; its
    // refresh-mode invariance is pinned in lazy_refresh_parity)
    let srbp = srbp::run_serial(&case.graph, &params(case, ResidualRefresh::Exact)).unwrap();
    assert_honest_eps(&srbp, case.eps, &format!("{}/srbp", case.label));
}

#[test]
fn randomized_schedule_differentials() {
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0xf022_a3a1_9e1c_55d7);
        for id in 0..CASES_PER_SEED {
            let case = gen_case(&mut rng, id);
            check_case(&case);
        }
    }
}

/// Multiqueue parameters ride the case fields that already exist:
/// selection workers reuse the engine-thread draw and the seed derives
/// from the rnbp seed draw, so the load-bearing `gen_case` draw stream
/// (shared with tests/session_warm_start.rs) is untouched.
fn mk_mq(case: &FuzzCase) -> Box<dyn Scheduler> {
    // queues/batch stay on auto (2·workers queues, frontier-scaled batch)
    Box::new(Multiqueue::new(
        case.engine_threads,
        0,
        0,
        case.rnbp_seed ^ 0x6d71_5f66_757a_7a21,
    ))
}

#[test]
fn mq_relaxed_envelope_differentials() {
    // Relaxed selection is deliberately nondeterministic at >1 worker,
    // so this leg asserts the envelope contract rather than digests:
    //
    // * every run is honest (no stall, no false Converged) — eager and
    //   lazy refresh both;
    // * when both mq and exact RBP converge, their fixed points agree
    //   at fixed-point tolerance (1e-2: relaxed pop order walks a
    //   different trajectory to the same attractor);
    // * across each seed's case set, mq converges at least as often as
    //   RBP on the same graphs (relaxation must not cost convergence
    //   on this matrix);
    // * relaxed accounting is conserved: per-solve worker commit counts
    //   sum to exactly the committed rows.
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0xf022_a3a1_9e1c_55d7);
        let (mut rbp_conv, mut mq_conv) = (0usize, 0usize);
        for id in 0..CASES_PER_SEED {
            let case = gen_case(&mut rng, id);
            for &engine in &engines_under_test() {
                let what = format!("{}/mq/{engine}", case.label);
                let rbp = run_one(&case, "rbp", engine, ResidualRefresh::Exact);

                let mut runs = Vec::new();
                for mode in [ResidualRefresh::Exact, ResidualRefresh::Lazy] {
                    let p = params(&case, mode);
                    let mut eng = mk_engine(&case, engine);
                    let mut s = mk_mq(&case);
                    let r = run(&case.graph, eng.as_mut(), s.as_mut(), &p).unwrap();
                    let which = format!("{what}/{mode:?}");
                    assert_honest_eps(&r, case.eps, &which);
                    assert_eq!(
                        r.worker_commits.iter().sum::<u64>(),
                        r.message_updates,
                        "{which}: worker commit counts don't reconcile"
                    );
                    if rbp.converged() && r.converged() {
                        for (i, (x, y)) in rbp
                            .marginals
                            .as_ref()
                            .unwrap()
                            .iter()
                            .zip(r.marginals.as_ref().unwrap())
                            .enumerate()
                        {
                            assert!(
                                (x - y).abs() < 1e-2,
                                "{which}: marginal[{i}] rbp {x} vs mq {y}"
                            );
                        }
                    }
                    runs.push(r);
                }
                rbp_conv += rbp.converged() as usize;
                // rate comparison on the eager run (runs[0]): lazy has
                // the cap-boundary stop asymmetry documented above
                mq_conv += runs[0].converged() as usize;
            }
        }
        assert!(
            mq_conv >= rbp_conv,
            "seed {root}: mq converged on {mq_conv} runs < rbp's {rbp_conv}"
        );
    }
}

#[test]
fn randomized_evidence_streams_warm_matches_cold() {
    // The serving differential, fuzzed: a warm Session absorbs a stream
    // of random evidence batches; after every warm solve, a cold run on
    // the identically mutated graph must land on the same fixed point
    // (marginals at fixed-point tolerance) for every scheduler × engine
    // × refresh mode. Tight eps so fixed points are well-separated from
    // the comparison tolerance.
    let mut compared = 0usize;
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0x5e55_1011_f22d);
        for id in 0..4 {
            let case = gen_case(&mut rng, id);
            for sched in ["lbp", "rbp", "rs", "rnbp"] {
                for &engine in &engines_under_test() {
                    for mode in MODES {
                        let what =
                            format!("{}/{sched}/{engine}/{mode:?} evidence stream", case.label);
                        let params = RunParams { eps: 1e-5, ..params(&case, mode) };
                        let mut warm = SessionBuilder::new(
                            case.graph.clone(),
                            mk_engine(&case, engine),
                            mk_sched(&case, sched),
                        )
                        .with_params(params.clone())
                        .build()
                        .unwrap();
                        warm.solve().unwrap();
                        let mut stream =
                            EvidenceStream::new(root ^ id as u64, 1 + id % 2, 0.6);
                        for _ in 0..3 {
                            let batch = stream.next_batch(warm.graph());
                            let updates: Vec<(usize, &[f32])> =
                                batch.iter().map(|(v, r)| (*v, r.as_slice())).collect();
                            warm.apply_evidence(&updates).unwrap();
                            let warm_ok = warm.solve().unwrap().converged();
                            // cold reference on the mutated graph
                            let mut eng = mk_engine(&case, engine);
                            let mut s = mk_sched(&case, sched);
                            let cold =
                                run(warm.graph(), eng.as_mut(), s.as_mut(), &params).unwrap();
                            assert_ne!(
                                cold.stop,
                                StopReason::Stalled,
                                "{what}: cold run stalled"
                            );
                            if !(warm_ok && cold.converged()) {
                                continue; // iteration-capped: no fixed point to compare
                            }
                            compared += 1;
                            let mw = warm.marginals().unwrap();
                            for (i, (x, y)) in
                                mw.iter().zip(cold.marginals.as_ref().unwrap()).enumerate()
                            {
                                assert!(
                                    (x - y).abs() < 1e-3,
                                    "{what}: marginal[{i}] warm {x} vs cold {y}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(compared > 0, "every stream case hit the iteration cap — vacuous differential");
}

#[test]
fn estimate_mode_differentials() {
    // The estimate rung fuzz leg — a separate fn so the load-bearing
    // `gen_case` draw stream and the typed three-mode matrix above
    // stay untouched; it replays the identical case stream. Estimate
    // selection ranks on unresolved bounds, so there is no digest
    // contract against exact; the assertions are:
    //
    // * honesty + the estimate counter shape (no step-3 refresh, no
    //   resolve stream, all rows materialized at commit) on every run;
    // * fixed-point marginal agreement with exact wherever both
    //   converge (sound bounds pin the destination, not the path);
    // * native ≡ parallel bit-identity per case (selection and engine
    //   are both deterministic under estimate for these schedulers);
    // * the row economy: on narrow-frontier draws (p = 1/16), a
    //   converged estimate run's total engine rows stay within 110% of
    //   lazy's — usually strictly below, but selection on stale bounds
    //   can buy extra iterations, so the fuzzer tolerates the overlap
    //   band and the parity harness owns the strict narrow-frontier
    //   claims.
    let mut compared = 0usize;
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0xf022_a3a1_9e1c_55d7);
        for id in 0..CASES_PER_SEED {
            let case = gen_case(&mut rng, id);
            for sched in ["lbp", "rbp", "rs", "rnbp"] {
                let mut per_engine: Vec<RunResult> = Vec::new();
                for &engine in &engines_under_test() {
                    let what = format!("{}/{sched}/{engine}/estimate", case.label);
                    let est = run_one(&case, sched, engine, ResidualRefresh::Estimate);
                    assert_honest_eps(&est, case.eps, &what);
                    assert_eq!(est.refresh_rows, 0, "{what}: estimate must not refresh");
                    assert_eq!(est.refresh_resolved, 0, "{what}: no resolve stream");
                    assert_eq!(est.refresh_skipped, 0, "{what}: defers, never skips");
                    assert_eq!(
                        est.engine_rows(),
                        est.commit_recompute_rows,
                        "{what}: rows outside commit materialization"
                    );

                    let exact = run_one(&case, sched, engine, ResidualRefresh::Exact);
                    if exact.converged() && est.converged() {
                        compared += 1;
                        for (i, (x, y)) in exact
                            .marginals
                            .as_ref()
                            .unwrap()
                            .iter()
                            .zip(est.marginals.as_ref().unwrap())
                            .enumerate()
                        {
                            assert!(
                                (x - y).abs() < 1e-3,
                                "{what}: marginal[{i}] exact {x} vs estimate {y}"
                            );
                        }
                    }

                    let narrow = match sched {
                        "rbp" => case.rbp_p <= 1.0 / 16.0,
                        "rs" => case.rs_p <= 1.0 / 16.0,
                        _ => false,
                    };
                    if narrow && est.converged() {
                        let lazy = run_one(&case, sched, engine, ResidualRefresh::Lazy);
                        if lazy.converged() {
                            assert!(
                                est.engine_rows() * 100 <= lazy.engine_rows() * 110,
                                "{what}: estimate {} engine rows vs lazy {} on a \
                                 narrow frontier",
                                est.engine_rows(),
                                lazy.engine_rows()
                            );
                        }
                    }
                    per_engine.push(est);
                }
                if per_engine.len() == 2 {
                    let (a, b) = (&per_engine[0], &per_engine[1]);
                    let what =
                        format!("{}/{sched}/estimate native-vs-parallel", case.label);
                    assert_eq!(a.stop, b.stop, "{what}");
                    assert_eq!(a.frontier_digest, b.frontier_digest, "{what}");
                    assert_bits_equal(
                        a.marginals.as_ref().unwrap(),
                        b.marginals.as_ref().unwrap(),
                        &what,
                    );
                }
            }

            // mq rides its envelope contract (no digests): honesty and
            // conserved relaxed accounting under estimate refresh
            for &engine in &engines_under_test() {
                let what = format!("{}/mq/{engine}/estimate", case.label);
                let p = params(&case, ResidualRefresh::Estimate);
                let mut eng = mk_engine(&case, engine);
                let mut s = mk_mq(&case);
                let r = run(&case.graph, eng.as_mut(), s.as_mut(), &p).unwrap();
                assert_honest_eps(&r, case.eps, &what);
                assert_eq!(r.refresh_rows, 0, "{what}: estimate must not refresh");
                assert_eq!(
                    r.worker_commits.iter().sum::<u64>(),
                    r.message_updates,
                    "{what}: worker commit counts don't reconcile"
                );
            }
        }
    }
    assert!(compared > 0, "no case converged under both exact and estimate — vacuous");
}

#[test]
fn randomized_arity_layout_differentials() {
    // Storage-layout fuzz leg (a separate fn with its own seed stream,
    // so the load-bearing `gen_case` draw sequence above is untouched):
    // random graphs with randomized per-vertex arities run in both the
    // padded envelope and their arity-exact CSR twin. Ragged rows
    // change reduction shapes, so the contract is the layout_parity
    // one — honesty in both layouts plus fixed-point marginal
    // agreement on converged runs; the bitwise uniform-arity contract
    // lives in tests/layout_parity.rs.
    let mut compared = 0usize;
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0xc5_1a_70_07);
        for id in 0..8 {
            let (glabel, env) = common::random_mixed_arity_mrf(&mut rng);
            let csr = env.to_csr();
            let eps = [1e-3f32, 1e-4][rng.below(2)];
            let p = RunParams {
                eps,
                max_iterations: 400,
                timeout: 1e9,
                cost_model: None,
                want_marginals: true,
                belief_refresh_every: 0,
                ..Default::default()
            };
            for sched in ["lbp", "rbp", "rs", "rnbp"] {
                for &engine in &engines_under_test() {
                    let what = format!("case{id}:{glabel}/{sched}/{engine}/layout");
                    let mk = |g: &Mrf| {
                        let mut eng = match engine {
                            "native" => Box::new(NativeEngine::new()) as Box<dyn MessageEngine>,
                            _ => Box::new(ParallelEngine::with_threads(2)),
                        };
                        let mut s: Box<dyn Scheduler> = match sched {
                            "lbp" => Box::new(Lbp::new()),
                            "rbp" => Box::new(Rbp::new(0.25)),
                            "rs" => Box::new(ResidualSplash::new(0.25, 2)),
                            _ => Box::new(Rnbp::new(0.7, 1.0, root)),
                        };
                        run(g, eng.as_mut(), s.as_mut(), &p).unwrap()
                    };
                    let a = mk(&env);
                    let b = mk(&csr);
                    assert_honest_eps(&a, eps, &format!("{what}/envelope"));
                    assert_honest_eps(&b, eps, &format!("{what}/csr"));
                    if a.converged() && b.converged() {
                        compared += 1;
                        // marginal reporting is dense `v * max_arity`
                        // rows under both layouts; only the live lanes
                        // of each row carry meaning
                        let (am, bm) =
                            (a.marginals.as_ref().unwrap(), b.marginals.as_ref().unwrap());
                        let stride = env.max_arity;
                        for v in 0..env.live_vertices {
                            for x in 0..env.arity_of(v) {
                                let (ma, mb) = (am[v * stride + x], bm[v * stride + x]);
                                assert!(
                                    (ma - mb).abs() < 1e-3,
                                    "{what}: vertex {v} lane {x}: {ma} vs {mb}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
    assert!(compared > 0, "no layout case converged in both layouts — vacuous");
}

#[test]
fn sampled_lazy_runs_keep_bounds_sound() {
    // The full-recompute audit is O(M·A·deg) per refresh point, so it
    // runs on a deterministic sample of cases rather than all of them.
    for root in root_seeds() {
        let mut rng = Rng::new(root ^ 0xf022_a3a1_9e1c_55d7);
        for id in 0..CASES_PER_SEED {
            let case = gen_case(&mut rng, id);
            if id % 6 != 0 {
                continue;
            }
            for sched in ["rbp", "rs"] {
                let what = format!("{}/{sched}/lazy-audit", case.label);
                let mut eng = mk_engine(&case, "native");
                let mut s = mk_sched(&case, sched);
                // reference engine must match the case's damping so the
                // audit compares identical arithmetic
                let mut auditor = BoundAuditor::new(
                    what.clone(),
                    NativeEngine::with_options(UpdateOptions {
                        semiring: Semiring::SumProduct,
                        damping: case.damping,
                    }),
                );
                let r = run_observed(
                    &case.graph,
                    eng.as_mut(),
                    s.as_mut(),
                    &params(&case, ResidualRefresh::Lazy),
                    &mut auditor,
                )
                .unwrap();
                assert!(auditor.audits > 0, "{what}: auditor never ran");
                assert_honest_eps(&r, case.eps, &what);
            }
        }
    }
}
