//! Differential harness for the lazy priority-ordered refresh
//! (`--residual-refresh lazy`) against the eager exact recompute and
//! PR 3's bounded skip, across every scheduler on small
//! Ising/Potts/chain instances.
//!
//! What is provable, and asserted here:
//!
//! * **Trajectory identity for the certified schedulers** — rbp, rnbp
//!   and rs resolve deferred residuals in certified boundary order (no
//!   unresolved bound above the last admitted exact residual), so their
//!   `lazy` runs select bit-identical frontier sequences and commit
//!   bit-identical rows: equal digests, iterate counts, message
//!   updates, stop reasons, and bitwise marginals vs `exact`.
//! * **lbp** takes the default resolve-all `select_lazy`, which *is*
//!   the eager refresh executed at selection time — also digest- and
//!   marginal-identical; the fixed-point tolerance the satellite
//!   contract asks for is implied and asserted separately.
//! * **Work reduction where the boundary is narrow** — on the
//!   narrow-frontier rs workload the lazy oracle resolves only
//!   ranking-relevant and selected edges, so it issues strictly fewer
//!   refresh rows than `bounded` (which eagerly recomputes every
//!   over-ε dirty edge) while being *identical* to `exact` (which
//!   bounded is not, for rs). The full-frontier rbp control shows the
//!   degenerate case: nothing sits outside the boundary, so lazy pays
//!   exactly the bounded/exact rows with identical digests.
//! * **Bound soundness under deferral** — at every refresh point the
//!   maintained upper bound of every (possibly deferred) edge
//!   dominates a from-scratch recompute, audited via the `RunObserver`
//!   seam exactly like the PR 3 harness.
//! * **srbp invariance** — the knob never touches the serial baseline.
//!
//! The engine matrix honors `BP_TEST_ENGINE` (`native` / `parallel`),
//! which CI loops over; unset, both engines run.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

mod common;

use bp_sched::coordinator::{
    run_observed, ResidualRefresh, RunParams, RunResult, SessionBuilder, StopReason,
};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::sched::{srbp, Lbp, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;
use common::{assert_bits_equal, engines_under_test, BoundAuditor};

const CERTIFIED_SCHEDULERS: [&str; 3] = ["rbp", "rs", "rnbp"];

fn test_graphs() -> Vec<(&'static str, Mrf)> {
    let mut rng = Rng::new(20_260_729);
    vec![
        (
            "ising6",
            DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap(),
        ),
        (
            "potts5_q3",
            DatasetSpec::Potts { n: 5, q: 3, c: 1.0 }.generate(&mut rng).unwrap(),
        ),
        (
            "chain40",
            DatasetSpec::Chain { n: 40, c: 5.0 }.generate(&mut rng).unwrap(),
        ),
    ]
}

fn mk_sched(name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(0.25)),
        "rs" => Box::new(ResidualSplash::new(0.25, 2)),
        "rnbp" => Box::new(Rnbp::synthetic(0.7, 11)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    match name {
        "native" => Box::new(NativeEngine::new()),
        "parallel" => Box::new(ParallelEngine::with_threads(4)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(mode: ResidualRefresh) -> RunParams {
    RunParams {
        want_marginals: true,
        timeout: 30.0,
        // untracked beliefs: every engine read re-derives from the
        // current messages, bit-identical to the auditor's reference
        belief_refresh_every: 0,
        residual_refresh: mode,
        ..Default::default()
    }
}

fn run_one(g: &Mrf, sched: &str, engine: &str, mode: ResidualRefresh) -> RunResult {
    // through the owning Session API (of which `run` is the shim)
    let mut session = SessionBuilder::new(g.clone(), mk_engine(engine), mk_sched(sched))
        .with_params(params(mode))
        .build()
        .unwrap();
    session.solve().unwrap();
    session.into_result().unwrap()
}

fn assert_identical(exact: &RunResult, lazy: &RunResult, what: &str) {
    assert_eq!(exact.stop, lazy.stop, "{what}: stop");
    assert_eq!(exact.iterations, lazy.iterations, "{what}: iterations");
    assert_eq!(
        exact.message_updates, lazy.message_updates,
        "{what}: message updates"
    );
    assert_eq!(
        exact.frontier_digest, lazy.frontier_digest,
        "{what}: the refresh modes selected different frontiers"
    );
    assert_bits_equal(
        exact.marginals.as_ref().unwrap(),
        lazy.marginals.as_ref().unwrap(),
        &format!("{what}: marginals"),
    );
}

#[test]
fn lazy_is_trajectory_identical_to_exact_for_certified_schedulers() {
    for (glabel, g) in &test_graphs() {
        for sched in CERTIFIED_SCHEDULERS {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine}");
                let exact = run_one(g, sched, engine, ResidualRefresh::Exact);
                let lazy = run_one(g, sched, engine, ResidualRefresh::Lazy);
                assert_eq!(exact.stop, StopReason::Converged, "{what}: exact");
                assert_identical(&exact, &lazy, &what);
                assert!(lazy.final_residual < params(ResidualRefresh::Lazy).eps, "{what}");
                // counter sanity: lazy defers instead of skipping, and
                // never resolves more than it deferred; resolutions are
                // the only lazy refresh rows
                assert_eq!(lazy.refresh_skipped, 0, "{what}");
                assert_eq!(exact.refresh_deferred, 0, "{what}");
                assert!(
                    lazy.refresh_resolved <= lazy.refresh_deferred,
                    "{what}: resolved {} > deferred {}",
                    lazy.refresh_resolved,
                    lazy.refresh_deferred
                );
                assert_eq!(lazy.refresh_resolved, lazy.refresh_rows, "{what}");
                // deferral means the lazy run never pays *more* refresh
                // rows than the eager one
                assert!(
                    lazy.refresh_rows <= exact.refresh_rows,
                    "{what}: lazy {} rows vs exact {}",
                    lazy.refresh_rows,
                    exact.refresh_rows
                );
            }
        }
    }
}

#[test]
fn lazy_lbp_matches_exact_at_fixed_point_and_beyond() {
    // The satellite contract for lbp is fixed-point tolerance; the
    // default resolve-all select_lazy actually delivers trajectory
    // identity (it is the eager refresh run at selection time), so
    // assert both — the tolerance bound documents the guaranteed
    // contract, the identity the implementation's stronger one.
    for (glabel, g) in &test_graphs() {
        for engine in engines_under_test() {
            let what = format!("{glabel}/lbp/{engine}");
            let exact = run_one(g, "lbp", engine, ResidualRefresh::Exact);
            let lazy = run_one(g, "lbp", engine, ResidualRefresh::Lazy);
            assert!(exact.converged() && lazy.converged(), "{what}");
            for (i, (x, y)) in exact
                .marginals
                .as_ref()
                .unwrap()
                .iter()
                .zip(lazy.marginals.as_ref().unwrap())
                .enumerate()
            {
                assert!((x - y).abs() < 1e-3, "{what}: marginal[{i}] {x} vs {y}");
            }
            assert_identical(&exact, &lazy, &what);
            assert!(lazy.refresh_deferred > 0, "{what}: nothing deferred");
        }
    }
}

#[test]
fn lazy_beats_bounded_on_narrow_frontier_rs_with_rbp_control() {
    // The headline of estimate-first scheduling: on a narrow-frontier
    // rs workload the lazy oracle pays only for ranking-relevant and
    // selected rows, strictly undercutting bounded's eager over-ε
    // recompute — while staying *identical* to exact (bounded only
    // agrees at fixed-point tolerance for rs). The full-frontier rbp
    // control has nothing outside its selection boundary: equal rows
    // across all three modes, identical digests.
    let mut rng = Rng::new(31);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();

    let run_mode = |mk: fn() -> Box<dyn Scheduler>, mode: ResidualRefresh| -> RunResult {
        let mut session = SessionBuilder::new(g.clone(), Box::new(NativeEngine::new()), mk())
            .with_params(params(mode))
            .build()
            .unwrap();
        session.solve().unwrap();
        session.into_result().unwrap()
    };

    // narrow-frontier rs: the paper-relevant splash workload
    let mk_rs: fn() -> Box<dyn Scheduler> = || Box::new(ResidualSplash::new(1.0 / 16.0, 2));
    let exact = run_mode(mk_rs, ResidualRefresh::Exact);
    let bounded = run_mode(mk_rs, ResidualRefresh::Bounded);
    let lazy = run_mode(mk_rs, ResidualRefresh::Lazy);
    assert!(exact.converged() && bounded.converged() && lazy.converged());
    assert_identical(&exact, &lazy, "rs narrow: lazy vs exact");
    assert!(
        lazy.refresh_rows < bounded.refresh_rows,
        "rs narrow: lazy {} rows vs bounded {} — estimate-first saved nothing",
        lazy.refresh_rows,
        bounded.refresh_rows
    );
    assert!(
        lazy.refresh_rows < exact.refresh_rows,
        "rs narrow: lazy {} rows vs exact {}",
        lazy.refresh_rows,
        exact.refresh_rows
    );
    assert!(lazy.refresh_deferred > lazy.refresh_resolved, "rs narrow: no row was saved");

    // full-frontier rbp control: every over-ε edge is inside the
    // boundary, so lazy degenerates to bounded-equal work
    let mk_rbp: fn() -> Box<dyn Scheduler> = || Box::new(Rbp::new(1.0));
    let exact = run_mode(mk_rbp, ResidualRefresh::Exact);
    let bounded = run_mode(mk_rbp, ResidualRefresh::Bounded);
    let lazy = run_mode(mk_rbp, ResidualRefresh::Lazy);
    assert!(exact.converged() && bounded.converged() && lazy.converged());
    assert_identical(&exact, &lazy, "rbp control: lazy vs exact");
    assert_eq!(exact.frontier_digest, bounded.frontier_digest, "rbp control");
    assert_eq!(
        lazy.refresh_rows, bounded.refresh_rows,
        "rbp control: full frontier must pay the full boundary"
    );
    assert_eq!(bounded.refresh_rows, exact.refresh_rows, "rbp control");
}

#[test]
fn bounds_stay_sound_under_lazy_deferral() {
    // The shared full-recompute auditor (tests/common) — here
    // exercising deferred (never-resolved) edges under lazy refresh.
    for (glabel, g) in &test_graphs() {
        for sched in ["lbp", "rbp", "rs", "rnbp"] {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine} lazy");
                let mut eng = mk_engine(engine);
                let mut s = mk_sched(sched);
                let mut auditor = BoundAuditor::new(what.clone(), NativeEngine::new());
                let r = run_observed(
                    g,
                    eng.as_mut(),
                    s.as_mut(),
                    &params(ResidualRefresh::Lazy),
                    &mut auditor,
                )
                .unwrap();
                assert!(auditor.audits > 1, "{what}: auditor never ran");
                assert_eq!(r.stop, StopReason::Converged, "{what}");
            }
        }
    }
}

#[test]
fn srbp_is_residual_refresh_invariant_across_all_modes() {
    // The serial baseline has no dirty-list refresh: the knob must not
    // change a single bit of its trajectory in any of the four modes.
    let mut rng = Rng::new(99);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();
    let a = srbp::run_serial(&g, &params(ResidualRefresh::Exact)).unwrap();
    for mode in [
        ResidualRefresh::Bounded,
        ResidualRefresh::Lazy,
        ResidualRefresh::Estimate,
    ] {
        let b = srbp::run_serial(&g, &params(mode)).unwrap();
        assert_eq!(a.stop, b.stop, "{mode:?}");
        assert_eq!(a.message_updates, b.message_updates, "{mode:?}");
        assert_eq!(a.frontier_digest, b.frontier_digest, "{mode:?}");
        assert_eq!(b.refresh_rows, 0, "{mode:?}");
        assert_eq!(b.refresh_skipped, 0, "{mode:?}");
        assert_eq!(b.refresh_deferred, 0, "{mode:?}");
        assert_eq!(b.refresh_resolved, 0, "{mode:?}");
        assert_bits_equal(
            a.marginals.as_ref().unwrap(),
            b.marginals.as_ref().unwrap(),
            &format!("srbp marginals, {mode:?}"),
        );
    }
}
