//! Concurrency stress for the Multiqueue selection layer: no committed
//! row is lost or duplicated between relaxed selection and serial
//! commit, at any worker count.
//!
//! The proof is counter conservation through two independent ledgers:
//!
//! * the scheduler's per-worker selected-row counts, which [`RunResult`]
//!   surfaces as a per-solve delta (`worker_commits`), must sum to
//!   exactly that solve's `message_updates`;
//! * the frontier's per-edge commit counters
//!   ([`Session::edge_commits`]), bumped once per committed row on the
//!   serial commit path, must sum to the `message_updates` total across
//!   every solve of the session's lifetime.
//!
//! A lost wave edge, a duplicated pop that survived claiming, or a
//! fallback row that dodged attribution would break one of the ledgers.
//! Small batches on a hot graph force many selection rounds and heavy
//! queue contention; evidence edits between solves re-heat the frontier
//! so the counters keep accumulating across warm solves.
//!
//! `BP_STRESS_THREADS` pins the worker count (the CI matrix runs 1 and
//! 4 in separate legs); unset, both run in-process.

use bp_sched::coordinator::campaign::EvidenceStream;
use bp_sched::coordinator::{ResidualRefresh, RunParams, SessionBuilder, StopReason};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::native::NativeEngine;
use bp_sched::sched::Multiqueue;
use bp_sched::util::Rng;

fn worker_counts() -> Vec<usize> {
    match std::env::var("BP_STRESS_THREADS") {
        Ok(s) => vec![s.parse().expect("BP_STRESS_THREADS must be a usize")],
        Err(_) => vec![1, 4],
    }
}

#[test]
fn commit_counters_conserve_across_workers_and_solves() {
    for workers in worker_counts() {
        let mut rng = Rng::new(97);
        let g = DatasetSpec::Ising { n: 8, c: 3.0 }.generate(&mut rng).unwrap();
        let params = RunParams {
            eps: 1e-4,
            max_iterations: 400,
            timeout: 1e9,
            cost_model: None,
            want_marginals: false,
            residual_refresh: ResidualRefresh::Exact,
            ..Default::default()
        };
        // batch 2: selection rounds stay tiny, so workers collide on the
        // same hot edges over and over — worst case for claim races
        let mut session = SessionBuilder::new(
            g,
            Box::new(NativeEngine::new()),
            Box::new(Multiqueue::new(workers, 0, 2, 5 + workers as u64)),
        )
        .with_params(params)
        .build()
        .unwrap();

        let mut total_updates = 0u64;
        let mut total_pops = 0u64;
        let mut stream = EvidenceStream::new(workers as u64, 3, 0.8);
        for solve in 0..4 {
            if solve > 0 {
                let batch = stream.next_batch(session.graph());
                let updates: Vec<(usize, &[f32])> =
                    batch.iter().map(|(v, r)| (*v, r.as_slice())).collect();
                session.apply_evidence(&updates).unwrap();
            }
            let r = session.solve().unwrap();
            let what = format!("w{workers}/solve{solve}");
            assert_ne!(r.stop, StopReason::Stalled, "{what}: stalled");
            assert!(r.message_updates > 0, "{what}: vacuous solve");
            assert_eq!(
                r.worker_commits.len(),
                workers,
                "{what}: one commit counter per worker"
            );
            // ledger 1: the scheduler's per-solve attribution is exact
            assert_eq!(
                r.worker_commits.iter().sum::<u64>(),
                r.message_updates,
                "{what}: worker commit counts don't reconcile"
            );
            total_updates += r.message_updates;
            total_pops += r.relaxed_pops;
        }
        // ledger 2: the frontier's per-edge counters saw every committed
        // row exactly once, across the whole warm session
        assert_eq!(
            session.edge_commits().iter().sum::<u64>(),
            total_updates,
            "w{workers}: per-edge commit counters don't reconcile"
        );
        assert!(
            total_pops > 0,
            "w{workers}: relaxed pop accounting never engaged"
        );
    }
}
