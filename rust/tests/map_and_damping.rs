//! Max-product (MAP) inference and damped BP — the paper's "integrates
//! naturally with many variants of BP" claim, exercised end-to-end
//! through both engines.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

use bp_sched::coordinator::{run, RunParams};
use bp_sched::datasets::{ising, DatasetSpec};
use bp_sched::engine::{
    map_decode, native::NativeEngine, pjrt::PjrtEngine, MessageEngine, Semiring,
    UpdateOptions,
};
use bp_sched::runtime::default_artifacts_dir;
use bp_sched::sched::{Lbp, Rnbp};
use bp_sched::util::Rng;
use bp_sched::Mrf;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

/// Brute-force MAP assignment by joint enumeration (tiny graphs only).
fn brute_map(g: &Mrf) -> Vec<usize> {
    let n = g.live_vertices;
    let card: Vec<usize> = (0..n).map(|v| g.arity_of(v)).collect();
    let total: usize = card.iter().product();
    assert!(total < 1 << 22, "graph too large for brute force");
    let mut best = (f64::NEG_INFINITY, vec![0usize; n]);
    let mut assign = vec![0usize; n];
    for idx in 0..total {
        let mut rem = idx;
        for v in (0..n).rev() {
            assign[v] = rem % card[v];
            rem /= card[v];
        }
        let mut s = 0.0f64;
        for v in 0..n {
            s += g.log_unary_at(v, assign[v]) as f64;
        }
        for e in (0..g.live_edges).step_by(2) {
            let (u, v) = (g.src[e] as usize, g.dst[e] as usize);
            s += g.log_pair_at(e, assign[u], assign[v]) as f64;
        }
        if s > best.0 {
            best = (s, assign.clone());
        }
    }
    best.1
}

fn map_energy(g: &Mrf, assign: &[usize]) -> f64 {
    let mut s = 0.0f64;
    for v in 0..g.live_vertices {
        s += g.log_unary_at(v, assign[v]) as f64;
    }
    for e in (0..g.live_edges).step_by(2) {
        let (u, v) = (g.src[e] as usize, g.dst[e] as usize);
        s += g.log_pair_at(e, assign[u], assign[v]) as f64;
    }
    s
}

#[test]
fn max_product_exact_on_trees_native() {
    // max-product BP is exact on trees: decoded MAP == brute force.
    let mut rng = Rng::new(51);
    for n in [6usize, 10, 14] {
        let g = bp_sched::datasets::chain::generate("c", n, 3.0, &mut rng).unwrap();
        let opts = UpdateOptions { semiring: Semiring::MaxProduct, damping: 0.0 };
        let mut eng = NativeEngine::with_options(opts);
        let params = RunParams {
            eps: 1e-7,
            want_marginals: true,
            cost_model: None,
            ..Default::default()
        };
        let r = run(&g, &mut eng, &mut Lbp::new(), &params).unwrap();
        assert!(r.converged());
        let decoded = map_decode(&g, r.marginals.as_ref().unwrap());
        let exact = brute_map(&g);
        // the *energies* must match (argmax can tie)
        let de = map_energy(&g, &decoded);
        let ee = map_energy(&g, &exact);
        assert!((de - ee).abs() < 1e-4, "chain {n}: {de} vs {ee}");
    }
}

#[test]
fn max_product_near_exact_on_small_ising() {
    let mut rng = Rng::new(53);
    let g = ising::generate("i", 4, 1.5, &mut rng).unwrap();
    let opts = UpdateOptions { semiring: Semiring::MaxProduct, damping: 0.2 };
    let mut eng = NativeEngine::with_options(opts);
    let params = RunParams {
        eps: 1e-6,
        want_marginals: true,
        cost_model: None,
        ..Default::default()
    };
    let r = run(&g, &mut eng, &mut Lbp::new(), &params).unwrap();
    if !r.converged() {
        return; // loopy max-product may oscillate; only judge fixed points
    }
    let decoded = map_decode(&g, r.marginals.as_ref().unwrap());
    let exact = brute_map(&g);
    let (de, ee) = (map_energy(&g, &decoded), map_energy(&g, &exact));
    // loopy MAP is approximate; must be close on an easy 4x4
    assert!(de >= ee - 0.5, "decoded energy {de} far below optimum {ee}");
}

#[test]
fn pjrt_max_product_matches_native() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(55);
    let g = DatasetSpec::Ising { n: 10, c: 2.0 }.generate(&mut rng).unwrap();
    let opts = UpdateOptions { semiring: Semiring::MaxProduct, damping: 0.0 };
    let mut native = NativeEngine::with_options(opts);
    let mut pjrt = PjrtEngine::from_default_dir_with(opts).unwrap();
    let logm = g.uniform_messages();
    let frontier: Vec<i32> = (0..g.live_edges as i32).collect();
    let a = native.candidates(&g, logm.as_slice(), &frontier).unwrap();
    let b = pjrt.candidates(&g, logm.as_slice(), &frontier).unwrap();
    for (x, y) in a.new_m.iter().zip(&b.new_m) {
        assert!((x - y).abs() < 5e-5, "{x} vs {y}");
    }
}

#[test]
fn pjrt_damping_matches_native() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(57);
    let g = DatasetSpec::Ising { n: 10, c: 2.5 }.generate(&mut rng).unwrap();
    let opts = UpdateOptions { semiring: Semiring::SumProduct, damping: 0.4 };
    let mut native = NativeEngine::with_options(opts);
    let mut pjrt = PjrtEngine::from_default_dir_with(opts).unwrap();
    // iterate a few committed rounds to compare at non-trivial states
    let mut logm = g.uniform_messages().as_slice().to_vec();
    let frontier: Vec<i32> = (0..g.live_edges as i32).collect();
    for _ in 0..3 {
        let a = native.candidates(&g, &logm, &frontier).unwrap();
        let b = pjrt.candidates(&g, &logm, &frontier).unwrap();
        for (x, y) in a.new_m.iter().zip(&b.new_m) {
            assert!((x - y).abs() < 5e-5, "{x} vs {y}");
        }
        logm.copy_from_slice(&a.new_m);
    }
}

#[test]
fn damping_rescues_oscillating_graphs() {
    // The classic use of damping: pick hard C=3 grids where undamped LBP
    // fails and check damped LBP converges at least as often.
    let mut undamped_ok = 0;
    let mut damped_ok = 0;
    let total = 4;
    for seed in 0..total {
        let mut rng = Rng::new(100 + seed);
        let g = ising::generate("i", 12, 3.0, &mut rng).unwrap();
        let params = RunParams {
            max_iterations: 3000,
            cost_model: None,
            ..Default::default()
        };
        let mut e0 = NativeEngine::new();
        let r0 = run(&g, &mut e0, &mut Lbp::new(), &params).unwrap();
        undamped_ok += r0.converged() as u32;
        let opts = UpdateOptions { semiring: Semiring::SumProduct, damping: 0.5 };
        let mut e1 = NativeEngine::with_options(opts);
        let r1 = run(&g, &mut e1, &mut Lbp::new(), &params).unwrap();
        damped_ok += r1.converged() as u32;
    }
    assert!(
        damped_ok >= undamped_ok,
        "damping should not hurt: {damped_ok} vs {undamped_ok}"
    );
    assert!(damped_ok > 0, "damped LBP should converge somewhere");
}

#[test]
fn rnbp_works_under_max_product() {
    // The scheduling layer is semiring-agnostic: RnBP + max-product.
    let mut rng = Rng::new(61);
    let g = ising::generate("i", 8, 1.5, &mut rng).unwrap();
    let opts = UpdateOptions { semiring: Semiring::MaxProduct, damping: 0.3 };
    let mut eng = NativeEngine::with_options(opts);
    let mut s = Rnbp::synthetic(0.7, 3);
    let params = RunParams {
        want_marginals: true,
        cost_model: None,
        ..Default::default()
    };
    let r = run(&g, &mut eng, &mut s, &params).unwrap();
    if r.converged() {
        let decoded = map_decode(&g, r.marginals.as_ref().unwrap());
        assert_eq!(decoded.len(), g.live_vertices);
    }
}
