//! Acceptance harness for the relaxed Multiqueue scheduler.
//!
//! mq's waves at >1 worker depend on thread interleaving, so the
//! pre-existing digest-parity harnesses cannot cover it. Its contract
//! is an *envelope* instead, pinned here on an explicit ising / potts /
//! chain matrix:
//!
//! * **Fixed-point agreement** — wherever both mq and exact-refresh RBP
//!   converge, their marginals agree at fixed-point tolerance, at every
//!   worker count.
//! * **Convergence rate** — over the matrix, mq converges at least as
//!   often as RBP on the same graphs and seeds: relaxation must not
//!   cost convergence here.
//! * **Strong determinism at the degenerate point** — one worker, one
//!   queue: two identical runs are bitwise identical (stop, digest,
//!   iteration count, marginals). This is what `--sched mq --threads 1
//!   --mq-queues 1` promises on the CLI.
//! * **Seed re-pin replay** — `Session::reset_scheduler_rng` makes a
//!   warm session's next solve match a fresh session built with the
//!   new seed, bitwise, for both randomized schedulers (rnbp, mq).
//!
//! `BP_FUZZ_SEED` pins one root seed (CI runs this harness in the
//! parallel-engine leg with seed 11); unset, all three run.

mod common;

use bp_sched::coordinator::campaign::EvidenceStream;
use bp_sched::coordinator::{
    ResidualRefresh, RunParams, RunResult, Session, SessionBuilder, StopReason,
};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{
    native::NativeEngine, parallel::ParallelEngine, MessageEngine, Semiring, UpdateOptions,
};
use bp_sched::sched::{Multiqueue, Rbp, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;
use common::{assert_bits_equal, engines_under_test};

const DEFAULT_ROOT_SEEDS: [u64; 3] = [11, 22, 33];

fn root_seeds() -> Vec<u64> {
    match std::env::var("BP_FUZZ_SEED") {
        Ok(s) => vec![s.parse().expect("BP_FUZZ_SEED must be a u64")],
        Err(_) => DEFAULT_ROOT_SEEDS.to_vec(),
    }
}

/// The acceptance matrix: one graph per dataset family, sized so the
/// full matrix stays fast while leaving real frontiers to relax over.
fn matrix(root: u64) -> Vec<(String, Mrf)> {
    let mut rng = Rng::new(root ^ 0x6d71_2d65_6e76);
    [
        DatasetSpec::Ising { n: 8, c: 2.5 },
        DatasetSpec::Potts { n: 6, q: 3, c: 1.0 },
        DatasetSpec::Chain { n: 40, c: 6.0 },
    ]
    .into_iter()
    .map(|spec| (spec.label(), spec.generate(&mut rng).unwrap()))
    .collect()
}

fn params() -> RunParams {
    RunParams {
        eps: 1e-4,
        max_iterations: 400,
        timeout: 1e9,
        cost_model: None,
        want_marginals: true,
        belief_refresh_every: 0,
        residual_refresh: ResidualRefresh::Exact,
        ..Default::default()
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    let opts = UpdateOptions {
        semiring: Semiring::SumProduct,
        damping: 0.0,
    };
    match name {
        "native" => Box::new(NativeEngine::with_options(opts)),
        "parallel" => Box::new(ParallelEngine::with_options_threads(opts, 2)),
        other => panic!("unknown engine {other}"),
    }
}

fn solve_fresh(g: &Mrf, engine: &str, sched: Box<dyn Scheduler>) -> RunResult {
    let mut s = SessionBuilder::new(g.clone(), mk_engine(engine), sched)
        .with_params(params())
        .build()
        .unwrap();
    s.solve().unwrap();
    s.into_result().unwrap()
}

#[test]
fn mq_fixed_points_agree_with_rbp_across_matrix() {
    for root in root_seeds() {
        let (mut rbp_conv, mut mq_conv) = (0usize, 0usize);
        for (label, g) in matrix(root) {
            for &engine in &engines_under_test() {
                let rbp = solve_fresh(&g, engine, Box::new(Rbp::new(0.25)));
                assert_ne!(
                    rbp.stop,
                    StopReason::Stalled,
                    "{label}/{engine}: rbp stalled"
                );
                rbp_conv += rbp.converged() as usize;
                for workers in [1usize, 2, 4] {
                    let what = format!("{label}/{engine}/w{workers}");
                    let mq = solve_fresh(
                        &g,
                        engine,
                        Box::new(Multiqueue::new(workers, 0, 0, root ^ workers as u64)),
                    );
                    assert_ne!(mq.stop, StopReason::Stalled, "{what}: mq stalled");
                    if mq.stop == StopReason::Converged {
                        assert!(
                            !mq.final_residual.is_nan() && mq.final_residual < 1e-4,
                            "{what}: Converged with hot residual {}",
                            mq.final_residual
                        );
                    }
                    assert_eq!(
                        mq.worker_commits.iter().sum::<u64>(),
                        mq.message_updates,
                        "{what}: worker commit counts don't reconcile"
                    );
                    // rate comparison at the ISSUE's >= 2 workers bar
                    // uses w=2; every worker count checks the fixed point
                    if workers == 2 {
                        mq_conv += mq.converged() as usize;
                    }
                    if !(rbp.converged() && mq.converged()) {
                        continue;
                    }
                    for (i, (x, y)) in rbp
                        .marginals
                        .as_ref()
                        .unwrap()
                        .iter()
                        .zip(mq.marginals.as_ref().unwrap())
                        .enumerate()
                    {
                        assert!(
                            (x - y).abs() < 1e-2,
                            "{what}: marginal[{i}] rbp {x} vs mq {y}"
                        );
                    }
                }
            }
        }
        assert!(
            mq_conv >= rbp_conv,
            "seed {root}: mq converged on {mq_conv} graphs < rbp's {rbp_conv}"
        );
    }
}

#[test]
fn single_worker_single_queue_is_bitwise_deterministic() {
    // The acceptance criterion behind `--sched mq --threads 1
    // --mq-queues 1`: the degenerate Multiqueue is an exact-replay
    // scheduler — two runs of the same seed agree bit for bit.
    for root in root_seeds() {
        for (label, g) in matrix(root) {
            let run = || solve_fresh(&g, "native", Box::new(Multiqueue::new(1, 1, 0, root)));
            let (a, b) = (run(), run());
            let what = format!("{label}/w1q1");
            assert_eq!(a.stop, b.stop, "{what}: stop");
            assert_eq!(a.iterations, b.iterations, "{what}: iterations");
            assert_eq!(a.message_updates, b.message_updates, "{what}: updates");
            assert_eq!(a.relaxed_pops, b.relaxed_pops, "{what}: relaxed pops");
            assert_eq!(
                a.frontier_digest, b.frontier_digest,
                "{what}: frontier digests diverged"
            );
            assert_bits_equal(
                a.marginals.as_ref().unwrap(),
                b.marginals.as_ref().unwrap(),
                &format!("{what}: marginals"),
            );
        }
    }
}

/// Replay discipline shared by the two randomized schedulers: a session
/// whose scheduler rng is re-pinned to seed `s` before a solve must
/// match, bitwise, a fresh session built with seed `s` — both on the
/// cold solve and again on a warm solve after identical evidence.
fn assert_reseed_replays(what: &str, g: &Mrf, mk: impl Fn(u64) -> Box<dyn Scheduler>) {
    let build = |seed: u64| -> Session {
        SessionBuilder::new(g.clone(), mk_engine("native"), mk(seed))
            .with_params(params())
            .build()
            .unwrap()
    };
    let mut x = build(111);
    x.reset_scheduler_rng(222);
    let mut y = build(222);
    let (dx, dy) = (x.solve().unwrap().frontier_digest, y.solve().unwrap().frontier_digest);
    assert_eq!(dx, dy, "{what}: cold replay digests diverged");
    assert_bits_equal(
        &x.marginals().unwrap(),
        &y.marginals().unwrap(),
        &format!("{what}: cold replay marginals"),
    );

    // identical evidence on both, then re-pin both to a third seed: the
    // warm solves must also be exact replays of each other
    let mut stream = EvidenceStream::new(7, 2, 0.6);
    let batch = stream.next_batch(x.graph());
    let updates: Vec<(usize, &[f32])> = batch.iter().map(|(v, r)| (*v, r.as_slice())).collect();
    x.apply_evidence(&updates).unwrap();
    y.apply_evidence(&updates).unwrap();
    x.reset_scheduler_rng(333);
    y.reset_scheduler_rng(333);
    let (dx, dy) = (x.solve().unwrap().frontier_digest, y.solve().unwrap().frontier_digest);
    assert_eq!(dx, dy, "{what}: warm replay digests diverged");
    assert_bits_equal(
        &x.marginals().unwrap(),
        &y.marginals().unwrap(),
        &format!("{what}: warm replay marginals"),
    );
}

#[test]
fn reset_scheduler_rng_replays_rnbp_and_mq() {
    let mut rng = Rng::new(42);
    let g = DatasetSpec::Ising { n: 7, c: 2.0 }.generate(&mut rng).unwrap();
    assert_reseed_replays("rnbp", &g, |s| Box::new(Rnbp::new(0.4, 0.9, s)));
    // one worker + one queue so the mq replay is bitwise, not just
    // distributional
    assert_reseed_replays("mq", &g, |s| Box::new(Multiqueue::new(1, 1, 0, s)));
}
