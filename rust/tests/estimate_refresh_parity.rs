//! Differential harness for the estimate-first refresh rung
//! (`--residual-refresh estimate`) against the exact eager recompute
//! and PR 6's lazy certified deferral, across the GPU schedulers on
//! small Ising/Potts/chain instances.
//!
//! What estimate mode *gives up*, and what this harness therefore
//! asserts instead of the lazy harness's trajectory identity:
//!
//! * **Fixed-point agreement, not digests** — selection ranks on
//!   propagated per-edge-contraction bounds, never resolving them, so
//!   frontier sequences legitimately diverge from `exact`. Soundness
//!   of the bounds still pins the *destination*: a converged estimate
//!   run has every true residual below ε, hence the same fixed point
//!   as `exact` at float tolerance.
//! * **Row accounting shape** — estimate performs no step-3 refresh at
//!   all (`refresh_rows == 0`, no resolve stream); every engine row
//!   after priming is a commit-time materialization, so
//!   `engine_rows() == commit_recompute_rows` — O(committed), where
//!   lazy pays O(selected + ranking boundary).
//! * **Work reduction on narrow frontiers** — the headline: on
//!   narrow-frontier rs and rbp p=1/64 workloads, estimate's total
//!   engine rows undercut (with tolerance — selection on stale bounds
//!   can cost extra iterations, this is not a theorem) lazy's, while
//!   the full-frontier rbp p=1 control pays approximately equal rows:
//!   with everything selected every iteration there is nothing left to
//!   avoid materializing.
//! * **Bound soundness with no resolution at all** — the shared
//!   full-recompute auditor (tests/common) checks that the per-edge
//!   contraction coefficients keep every propagated bound above the
//!   true residual at each selection boundary, the property the whole
//!   rung rests on.
//!
//! The engine matrix honors `BP_TEST_ENGINE` (`native` / `parallel`),
//! which CI loops over; unset, both engines run.

// One-shot harness code: the deprecated run_observed() shim is
// exercised here on purpose (kept-for-one-release API).
#![allow(deprecated)]

mod common;

use bp_sched::coordinator::{
    run_observed, ResidualRefresh, RunParams, RunResult, SessionBuilder, StopReason,
};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::sched::{Lbp, Multiqueue, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;
use common::{engines_under_test, BoundAuditor};

/// The schedulers the estimate rung targets (srbp has no dirty list;
/// lbp rides the trait default and is covered by the auditor test).
const GPU_SCHEDULERS: [&str; 4] = ["rbp", "rs", "rnbp", "mq"];

fn test_graphs() -> Vec<(&'static str, Mrf)> {
    let mut rng = Rng::new(20_260_729);
    vec![
        (
            "ising6",
            DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap(),
        ),
        (
            "potts5_q3",
            DatasetSpec::Potts { n: 5, q: 3, c: 1.0 }.generate(&mut rng).unwrap(),
        ),
        (
            "chain40",
            DatasetSpec::Chain { n: 40, c: 5.0 }.generate(&mut rng).unwrap(),
        ),
    ]
}

fn mk_sched(name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(0.25)),
        "rs" => Box::new(ResidualSplash::new(0.25, 2)),
        "rnbp" => Box::new(Rnbp::synthetic(0.7, 11)),
        // one worker, one queue: the fully serial, seeded Multiqueue
        "mq" => Box::new(Multiqueue::new(1, 1, 0, 17)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    match name {
        "native" => Box::new(NativeEngine::new()),
        "parallel" => Box::new(ParallelEngine::with_threads(4)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(mode: ResidualRefresh) -> RunParams {
    RunParams {
        want_marginals: true,
        timeout: 30.0,
        // untracked beliefs: the auditor's reference engine must
        // perform identical operations to the run's engine
        belief_refresh_every: 0,
        residual_refresh: mode,
        ..Default::default()
    }
}

fn run_one(g: &Mrf, sched: &str, engine: &str, mode: ResidualRefresh) -> RunResult {
    let mut session = SessionBuilder::new(g.clone(), mk_engine(engine), mk_sched(sched))
        .with_params(params(mode))
        .build()
        .unwrap();
    session.solve().unwrap();
    session.into_result().unwrap()
}

/// Estimate never refreshes at selection time: the entire row budget
/// is commit-time materialization.
fn assert_estimate_counter_shape(r: &RunResult, what: &str) {
    assert_eq!(r.refresh_rows, 0, "{what}: estimate must not refresh");
    assert_eq!(r.refresh_resolved, 0, "{what}: estimate has no resolve stream");
    assert_eq!(r.refresh_skipped, 0, "{what}: estimate defers, it never skips");
    assert!(r.refresh_deferred > 0, "{what}: nothing was ever deferred");
    assert!(r.commit_recompute_rows > 0, "{what}: no wave materialized rows");
    assert_eq!(r.engine_rows(), r.commit_recompute_rows, "{what}");
    assert!(
        r.commit_recompute_rows <= r.message_updates,
        "{what}: materialized more rows than it committed messages"
    );
}

#[test]
fn estimate_matches_exact_at_fixed_point() {
    let eps = params(ResidualRefresh::Estimate).eps;
    for (glabel, g) in &test_graphs() {
        for sched in GPU_SCHEDULERS {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine}");
                let exact = run_one(g, sched, engine, ResidualRefresh::Exact);
                let est = run_one(g, sched, engine, ResidualRefresh::Estimate);
                assert_eq!(exact.stop, StopReason::Converged, "{what}: exact");
                assert_eq!(est.stop, StopReason::Converged, "{what}: estimate");
                // converged bounds dominate true residuals, so the
                // final residual is genuinely below eps
                assert!(est.final_residual < eps, "{what}: {}", est.final_residual);
                assert_estimate_counter_shape(&est, &what);
                assert_eq!(exact.commit_recompute_rows, 0, "{what}: exact mid-wave");
                // same fixed point at float tolerance — trajectories
                // differ (bound-ranked selection), destination cannot
                for (i, (x, y)) in exact
                    .marginals
                    .as_ref()
                    .unwrap()
                    .iter()
                    .zip(est.marginals.as_ref().unwrap())
                    .enumerate()
                {
                    assert!((x - y).abs() < 1e-3, "{what}: marginal[{i}] {x} vs {y}");
                }
            }
        }
    }
}

#[test]
fn estimate_rows_approach_committed_on_narrow_frontiers() {
    // The headline win metric: on narrow frontiers estimate's total
    // engine rows (== commit-time materializations) undercut lazy's
    // O(selected + ranking boundary). Not a theorem — bound-ranked
    // selection can buy extra iterations — so the comparison carries a
    // 10% tolerance; the counter-shape assertions stay strict.
    let mut rng = Rng::new(31);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();

    let run_mode = |mk: &dyn Fn() -> Box<dyn Scheduler>, mode: ResidualRefresh| -> RunResult {
        let mut session = SessionBuilder::new(g.clone(), Box::new(NativeEngine::new()), mk())
            .with_params(params(mode))
            .build()
            .unwrap();
        session.solve().unwrap();
        session.into_result().unwrap()
    };

    let within = |est: &RunResult, lazy: &RunResult, factor_pct: u64, what: &str| {
        let (e, l) = (est.engine_rows(), lazy.engine_rows());
        assert!(
            e * 100 <= l * factor_pct,
            "{what}: estimate {e} engine rows vs lazy {l} (allowed {factor_pct}%)"
        );
    };

    // narrow-frontier rs: the paper-relevant splash workload
    let mk_rs: Box<dyn Fn() -> Box<dyn Scheduler>> =
        Box::new(|| Box::new(ResidualSplash::new(1.0 / 16.0, 2)));
    let lazy = run_mode(&*mk_rs, ResidualRefresh::Lazy);
    let est = run_mode(&*mk_rs, ResidualRefresh::Estimate);
    assert!(lazy.converged() && est.converged(), "rs narrow");
    assert_estimate_counter_shape(&est, "rs narrow");
    within(&est, &lazy, 110, "rs narrow");

    // narrow-frontier rbp: two edges per iteration on this instance
    let mk_rbp_narrow: Box<dyn Fn() -> Box<dyn Scheduler>> =
        Box::new(|| Box::new(Rbp::new(1.0 / 64.0)));
    let lazy = run_mode(&*mk_rbp_narrow, ResidualRefresh::Lazy);
    let est = run_mode(&*mk_rbp_narrow, ResidualRefresh::Estimate);
    assert!(lazy.converged() && est.converged(), "rbp narrow");
    assert_estimate_counter_shape(&est, "rbp narrow");
    within(&est, &lazy, 110, "rbp narrow");

    // full-frontier rbp control: everything over ε is selected every
    // iteration, so there is nothing left to avoid materializing —
    // estimate pays approximately lazy's rows (both directions, 50%
    // tolerance: trajectories differ, magnitudes must not)
    let mk_rbp_full: Box<dyn Fn() -> Box<dyn Scheduler>> = Box::new(|| Box::new(Rbp::new(1.0)));
    let lazy = run_mode(&*mk_rbp_full, ResidualRefresh::Lazy);
    let est = run_mode(&*mk_rbp_full, ResidualRefresh::Estimate);
    assert!(lazy.converged() && est.converged(), "rbp control");
    assert_estimate_counter_shape(&est, "rbp control");
    within(&est, &lazy, 150, "rbp control upper");
    let (e, l) = (est.engine_rows(), lazy.engine_rows());
    assert!(
        l * 100 <= e * 150,
        "rbp control lower: estimate {e} engine rows vs lazy {l} — the full \
         frontier should leave estimate no rows to save"
    );
}

#[test]
fn bounds_stay_sound_with_no_resolution_at_all() {
    // The shared full-recompute auditor — here exercising the per-edge
    // contraction coefficients with *zero* selection-time resolution:
    // every bound the scheduler ever ranks on must dominate a
    // from-scratch recompute of its edge. lbp joins the matrix (trait
    // default estimate path) for coverage of the resolve-all shape.
    for (glabel, g) in &test_graphs() {
        for sched in ["lbp", "rbp", "rs", "rnbp", "mq"] {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine} estimate");
                let mut eng = mk_engine(engine);
                let mut s = mk_sched(sched);
                let mut auditor = BoundAuditor::new(what.clone(), NativeEngine::new());
                let r = run_observed(
                    g,
                    eng.as_mut(),
                    s.as_mut(),
                    &params(ResidualRefresh::Estimate),
                    &mut auditor,
                )
                .unwrap();
                assert!(auditor.audits > 1, "{what}: auditor never ran");
                assert_eq!(r.stop, StopReason::Converged, "{what}");
            }
        }
    }
}
