//! Integration: the PJRT engine (AOT JAX/Pallas artifacts) and the native
//! Rust engine must agree to float tolerance on real graphs.
//!
//! Requires `make artifacts` to have run; tests are skipped (pass
//! trivially with a note) when the artifacts directory is missing so
//! `cargo test` works in a fresh checkout.

use bp_sched::datasets::{chain, ising, protein, DatasetSpec};
use bp_sched::engine::{native::NativeEngine, pjrt::PjrtEngine, MessageEngine};
use bp_sched::runtime::default_artifacts_dir;
use bp_sched::util::Rng;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol,
            "{what}[{i}]: native={x} pjrt={y} (|d|={})",
            (x - y).abs()
        );
    }
}

fn parity_on(g: &bp_sched::Mrf, frontiers: &[Vec<i32>], tol: f32) {
    let mut native = NativeEngine::new();
    let mut pjrt = PjrtEngine::from_default_dir().expect("open artifacts");
    let m = g.uniform_messages();

    // iterate a few rounds committing the native candidates so the two
    // engines are compared at multiple (non-uniform) message states
    let mut logm = m.as_slice().to_vec();
    for (round, frontier) in frontiers.iter().enumerate() {
        let a = native.candidates(g, &logm, frontier).unwrap();
        let b = pjrt.candidates(g, &logm, frontier).unwrap();
        assert_close(&a.new_m, &b.new_m, tol, &format!("round{round}.new_m"));
        assert_close(
            &a.residuals,
            &b.residuals,
            tol,
            &format!("round{round}.residuals"),
        );
        // commit
        let am = g.max_arity;
        for (i, &e) in frontier.iter().enumerate() {
            if e >= 0 {
                let e = e as usize;
                logm[e * am..(e + 1) * am].copy_from_slice(a.row(i, am));
            }
        }
    }

    let ma = native.marginals(g, &logm).unwrap();
    let mb = pjrt.marginals(g, &logm).unwrap();
    assert_close(&ma, &mb, tol, "marginals");
}

#[test]
fn ising10_full_and_partial_frontiers() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(101);
    let g = ising::generate("ising10", 10, 2.5, &mut rng).unwrap();
    let all: Vec<i32> = (0..g.live_edges as i32).collect();
    let mut some: Vec<i32> = (0..g.live_edges as i32).step_by(3).collect();
    rng.shuffle(&mut some);
    let few: Vec<i32> = vec![5, 17, 200];
    parity_on(&g, &[all.clone(), some, few, all], 5e-5);
}

#[test]
fn chain_large_bucket() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(102);
    let g = chain::generate("chain20k", 20_000, 10.0, &mut rng).unwrap();
    let all: Vec<i32> = (0..g.live_edges as i32).collect();
    parity_on(&g, &[all], 5e-5);
}

#[test]
fn protein_variable_arity() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(103);
    let g = protein::generate("protein", &Default::default(), &mut rng).unwrap();
    let all: Vec<i32> = (0..g.live_edges as i32).collect();
    let mut half: Vec<i32> = (0..g.live_edges as i32).step_by(2).collect();
    rng.shuffle(&mut half);
    // protein residuals/messages span a large dynamic range; tolerance is
    // scaled accordingly (f32 LSE over 81 lanes)
    parity_on(&g, &[all.clone(), half, all], 5e-4);
}

#[test]
fn dataset_specs_generate_into_manifest_envelopes() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let rt = bp_sched::runtime::Runtime::from_default_dir().unwrap();
    for spec in [
        DatasetSpec::Ising { n: 10, c: 2.0 },
        DatasetSpec::Ising { n: 40, c: 2.5 },
        DatasetSpec::Chain { n: 20_000, c: 10.0 },
        DatasetSpec::Protein,
    ] {
        let mut rng = Rng::new(7);
        let g = spec.generate(&mut rng).unwrap();
        let class = rt.class(&g.class_name).unwrap();
        assert_eq!(g.num_vertices, class.num_vertices, "{}", g.class_name);
        assert_eq!(g.num_edges, class.num_edges, "{}", g.class_name);
        assert_eq!(g.max_arity, class.arity, "{}", g.class_name);
        assert_eq!(g.max_in_degree, class.max_in_degree, "{}", g.class_name);
    }
}
