//! End-to-end: full stack (dataset -> scheduler -> PJRT engine -> AOT
//! artifacts) converges and matches exact inference on tractable graphs.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

use bp_sched::coordinator::{run, RunParams};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{native::NativeEngine, pjrt::PjrtEngine};
use bp_sched::exact;
use bp_sched::runtime::default_artifacts_dir;
use bp_sched::sched::{self, srbp, Lbp, Rnbp};
use bp_sched::util::Rng;

fn artifacts_ready() -> bool {
    default_artifacts_dir().join("manifest.txt").exists()
}

#[test]
fn pjrt_rnbp_converges_on_ising10() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(42);
    let g = DatasetSpec::Ising { n: 10, c: 2.0 }.generate(&mut rng).unwrap();
    let mut eng = PjrtEngine::from_default_dir().unwrap();
    let mut s = Rnbp::synthetic(0.7, 1);
    let params = RunParams { want_marginals: true, ..Default::default() };
    let r = run(&g, &mut eng, &mut s, &params).unwrap();
    assert!(r.converged(), "{:?} after {} iters", r.stop, r.iterations);
    let m = r.marginals.unwrap();
    for v in 0..g.live_vertices {
        let s: f32 = m[v * 2..v * 2 + 2].iter().sum();
        assert!((s - 1.0).abs() < 1e-4);
    }
}

#[test]
fn pjrt_and_native_runs_agree() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(7);
    let g = DatasetSpec::Ising { n: 10, c: 2.0 }.generate(&mut rng).unwrap();
    let params = RunParams {
        eps: 1e-5,
        want_marginals: true,
        // pin the drift guard to its bit-identical cadence: the native
        // engine honors commit tracking while pjrt ignores it, and this
        // test asserts iteration-exact agreement between the two — at
        // K=1 the tracked path provably equals gather-per-call, so the
        // comparison stays about the engines, not belief maintenance
        belief_refresh_every: 1,
        ..Default::default()
    };
    let mut native = NativeEngine::new();
    let mut s1 = Lbp::new();
    let a = run(&g, &mut native, &mut s1, &params).unwrap();
    let mut pjrt = PjrtEngine::from_default_dir().unwrap();
    let mut s2 = Lbp::new();
    let b = run(&g, &mut pjrt, &mut s2, &params).unwrap();
    assert_eq!(a.converged(), b.converged());
    assert_eq!(a.iterations, b.iterations, "same schedule, same iterations");
    for (x, y) in a.marginals.unwrap().iter().zip(&b.marginals.unwrap()) {
        assert!((x - y).abs() < 1e-3);
    }
}

#[test]
fn bp_matches_exact_on_tractable_ising() {
    // Fig 5 in miniature: KL(exact || BP) small on Ising 10x10 C=2.
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(5);
    let g = DatasetSpec::Ising { n: 10, c: 2.0 }.generate(&mut rng).unwrap();
    let params = RunParams {
        want_marginals: true,
        ..Default::default()
    };
    let mut eng = PjrtEngine::from_default_dir().unwrap();
    let mut s = Rnbp::synthetic(0.7, 3);
    let r = run(&g, &mut eng, &mut s, &params).unwrap();
    assert!(r.converged());
    let exact_m = exact::exact_marginals(&g).unwrap();
    let kl = exact::kl::mean_marginal_kl(&exact_m, &r.marginals.unwrap(), g.max_arity);
    // loopy BP is approximate on loopy graphs; C=2 is the paper's "easy"
    // setting where BP is near-exact
    assert!(kl < 0.05, "mean KL too high: {kl}");

    // SRBP achieves the same quality (paper: "same quality of result")
    let r2 = srbp::run_serial(&g, &params).unwrap();
    assert!(r2.converged());
    let kl2 = exact::kl::mean_marginal_kl(&exact_m, &r2.marginals.unwrap(), g.max_arity);
    assert!((kl - kl2).abs() < 0.02, "RnBP {kl} vs SRBP {kl2}");
}

#[test]
fn protein_rnbp_converges_with_paper_params() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rng = Rng::new(9);
    let g = DatasetSpec::Protein.generate(&mut rng).unwrap();
    let mut eng = PjrtEngine::from_default_dir().unwrap();
    // paper Fig 4f: LowP = 0.4, HighP = 0.9
    let mut s = Rnbp::new(0.4, 0.9, 17);
    // generous wallclock: `cargo test` runs suites in parallel threads on
    // this single-core box, so each run can be slowed several-fold
    let params = RunParams { timeout: 400.0, ..Default::default() };
    let r = run(&g, &mut eng, &mut s, &params).unwrap();
    assert!(
        r.converged(),
        "{:?} iters={} res={}",
        r.stop,
        r.iterations,
        r.final_residual
    );
}

#[test]
fn table_iv_registry() {
    let reg = sched::algorithm_registry();
    assert_eq!(reg.len(), 4);
}
