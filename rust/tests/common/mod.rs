//! Shared support for the differential integration harnesses
//! (`residual_bound_parity`, `lazy_refresh_parity`, `fuzz_schedules`,
//! `session_warm_start`): the random-MRF sampler, the engine matrix
//! switch, bitwise comparison, and the full-recompute residual-bound
//! auditor — one implementation, so a change to the audit contract
//! (e.g. the jitter cushion) cannot silently leave a sibling harness
//! asserting the old one.
#![allow(dead_code)] // each including test binary uses a subset

use bp_sched::coordinator::{ResidualAudit, RunObserver, SLACK_CUSHION};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{native::NativeEngine, CandidateBatch, MessageEngine};
use bp_sched::graph::MrfBuilder;
use bp_sched::util::Rng;
use bp_sched::Mrf;

/// One random small MRF (ising / potts / chain mix with randomized
/// size and coupling) — the generator the fuzz and warm-start
/// harnesses share. Consumes a fixed number of draws per shape arm, so
/// callers' case streams stay reproducible per seed.
pub fn random_mrf(rng: &mut Rng) -> (String, Mrf) {
    let (spec, glabel) = match rng.below(3) {
        0 => {
            let n = 4 + rng.below(3); // 4..6
            let c = rng.range(0.5, 2.5);
            (DatasetSpec::Ising { n, c }, format!("ising{n}x{c:.2}"))
        }
        1 => {
            let n = 4 + rng.below(2); // 4..5
            let q = 2 + rng.below(3); // 2..4
            let c = rng.range(0.5, 1.5);
            (DatasetSpec::Potts { n, q, c }, format!("potts{n}q{q}x{c:.2}"))
        }
        _ => {
            let n = 10 + rng.below(31); // 10..40
            let c = rng.range(1.0, 8.0);
            (DatasetSpec::Chain { n, c }, format!("chain{n}x{c:.2}"))
        }
    };
    let graph = spec.generate(rng).unwrap();
    (glabel, graph)
}

/// One random small MRF with *randomized per-vertex arities* (2..=5)
/// over a random connected structure (spanning tree + extra chords) —
/// the sampler the layout-parity fuzz legs use to exercise ragged
/// (CSR) rows against the padded envelope. Built through the envelope
/// builder so callers can diff `g` against `g.to_csr()`.
pub fn random_mixed_arity_mrf(rng: &mut Rng) -> (String, Mrf) {
    let nv = 6 + rng.below(8); // 6..13
    let arities: Vec<usize> = (0..nv).map(|_| 2 + rng.below(4)).collect(); // 2..5
    let max_a = arities.iter().copied().max().unwrap();
    let mut b = MrfBuilder::new("fuzzmix", max_a);
    for &a in &arities {
        let row: Vec<f32> = (0..a).map(|_| rng.range(-1.0, 1.0) as f32).collect();
        b.add_vertex(&row);
    }
    // spanning tree keeps it connected; chords add loops
    let mut edges = std::collections::BTreeSet::new();
    for v in 1..nv {
        edges.insert((rng.below(v), v));
    }
    for _ in 0..rng.below(nv) {
        let (u, v) = (rng.below(nv), rng.below(nv));
        if u != v {
            edges.insert((u.min(v), u.max(v)));
        }
    }
    for &(u, v) in &edges {
        let table: Vec<f32> = (0..arities[u] * arities[v])
            .map(|_| rng.range(-0.8, 0.8) as f32)
            .collect();
        b.add_edge(u, v, &table);
    }
    (
        format!("mix{nv}a{max_a}e{}", edges.len()),
        b.build(None).unwrap(),
    )
}

/// Engine matrix honoring `BP_TEST_ENGINE` (`native` / `parallel`),
/// which CI loops over; unset, both engines run.
pub fn engines_under_test() -> Vec<&'static str> {
    match std::env::var("BP_TEST_ENGINE").as_deref() {
        Ok("native") => vec!["native"],
        Ok("parallel") => vec!["parallel"],
        _ => vec!["native", "parallel"],
    }
}

pub fn assert_bits_equal(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "{what}[{i}]: {x:?} vs {y:?}");
    }
}

/// Recomputes every live residual from the audited messages with an
/// untracked reference engine and checks the maintained bounds:
///
/// * **soundness** — each edge's upper bound `res + slack (+ cushion)`
///   dominates the true residual, at every refresh point (this is what
///   makes bounded skips and lazy deferrals safe);
/// * **convergence honesty** — whenever the maintained bounds say
///   "converged" (exactly when the coordinator would stop Converged),
///   a full recompute agrees up to the jitter cushion.
///
/// The reference engine is caller-provided so harnesses that randomize
/// engine options (damping) audit against matching arithmetic; runs
/// must use `belief_refresh_every = 0` so the run's engine and this
/// reference perform identical operations.
pub struct BoundAuditor {
    what: String,
    eng: NativeEngine,
    batch: CandidateBatch,
    frontier: Vec<i32>,
    pub audits: usize,
}

impl BoundAuditor {
    pub fn new(what: String, reference: NativeEngine) -> BoundAuditor {
        BoundAuditor {
            what,
            eng: reference,
            batch: CandidateBatch::default(),
            frontier: Vec::new(),
            audits: 0,
        }
    }
}

impl RunObserver for BoundAuditor {
    fn on_state(&mut self, a: &ResidualAudit) {
        self.audits += 1;
        if self.frontier.len() != a.live {
            self.frontier = (0..a.live as i32).collect();
        }
        self.eng
            .candidates_into(a.mrf, a.logm, &self.frontier, &mut self.batch)
            .unwrap();
        let mut all_bounds_converged = true;
        for e in 0..a.live {
            let truth = self.batch.residuals[e];
            let bound = a.bound(e);
            assert!(
                bound + SLACK_CUSHION >= truth,
                "{}: audit {}, edge {e}: bound {bound} < true residual {truth} \
                 (res {}, slack {})",
                self.what,
                self.audits,
                a.res[e],
                a.slack[e]
            );
            if bound >= a.eps {
                all_bounds_converged = false;
            }
        }
        if all_bounds_converged {
            for e in 0..a.live {
                let truth = self.batch.residuals[e];
                assert!(
                    truth < a.eps + SLACK_CUSHION,
                    "{}: declared converged but edge {e} has true residual {truth} \
                     >= eps {}",
                    self.what,
                    a.eps
                );
            }
        }
    }
}
