//! Tier-1 gate for `bp-lint` (`bp_sched::util::lint`).
//!
//! Two halves: (1) the tree gate — the crate's own `src/` and
//! `tests/` must scan clean, with every waiver carrying a reason and
//! the waiver count pinned so the escape hatch can't quietly grow;
//! (2) per-rule positive/negative fixtures, where each positive
//! fixture reproduces the historical bug pattern the rule exists to
//! catch (PR 3 NaN-unsafe float sort, PR 7 silent edge-id wrap,
//! PR 9 nondeterministic report inputs, plus the unjustified-atomic
//! and bare-unsafe patterns audited in this PR).

use bp_sched::util::lint::{lint_crate, lint_source, SourceKind};

fn rules_hit(label: &str, src: &str, kind: SourceKind) -> Vec<&'static str> {
    lint_source(label, src, kind)
        .violations
        .iter()
        .map(|v| v.rule)
        .collect()
}

#[test]
fn repo_is_lint_clean() {
    let crate_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = lint_crate(crate_dir).expect("walk crate sources");
    assert!(
        report.files >= 70,
        "suspiciously few files scanned: {}",
        report.files
    );
    assert!(report.ok(), "unwaived lint violations:\n{}", report.render());
    for (v, reason) in &report.waived {
        assert!(!reason.is_empty(), "reasonless waiver at {}:{}", v.file, v.line);
    }
    // Keep the escape hatch small; raising this number is a review
    // decision, not a drive-by.
    assert!(
        report.waived.len() <= 4,
        "waiver count grew:\n{}",
        report.render()
    );
}

// ---- float-ord: the PR 3 class -------------------------------------

#[test]
fn float_ord_catches_partial_cmp_sort() {
    // Verbatim shape of the pre-PR 3 bug: NaN residuals make
    // partial_cmp panic (or silently missort with unwrap_or).
    let src = r#"
pub fn rank(xs: &mut Vec<(f32, usize)>) {
    xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
}
"#;
    let hit = rules_hit("src/sample.rs", src, SourceKind::Lib);
    assert!(hit.contains(&"float-ord"), "{hit:?}");
}

#[test]
fn float_ord_catches_relational_comparator() {
    let src = r#"
use std::cmp::Ordering::{Greater, Less};
pub fn rank(xs: &mut [(f32, usize)]) {
    xs.sort_by(|a, b| if a.0 < b.0 { Less } else { Greater });
}
"#;
    let hit = rules_hit("src/sample.rs", src, SourceKind::Lib);
    assert!(hit.contains(&"float-ord"), "{hit:?}");
}

#[test]
fn float_ord_allows_total_cmp_and_delegating_partial_ord() {
    // The QEntry pattern: integer-keyed Ord, PartialOrd delegating to
    // it. Must lint clean with zero waivers (the drive-by allowlist).
    let src = r#"
#[derive(PartialEq, Eq)]
pub struct Entry {
    key: u32,
    edge: i32,
}
impl Ord for Entry {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.key.cmp(&o.key)
    }
}
impl PartialOrd for Entry {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
pub fn rank(xs: &mut [f32]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}
"#;
    let fr = lint_source("src/sample.rs", src, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
    assert!(fr.waived.is_empty());
}

// ---- narrowing-cast: the PR 7 class --------------------------------

#[test]
fn narrowing_cast_catches_silent_edge_id_wrap() {
    // Verbatim shape of the pre-PR 7 bug: `e as i32` wraps past
    // i32::MAX and emits negative edge ids into waves.
    let src = r#"
pub fn wave(live: usize) -> Vec<i32> {
    let mut w = Vec::new();
    for e in 0..live {
        w.push(e as i32);
    }
    w
}
"#;
    let hit = rules_hit("src/sample.rs", src, SourceKind::Lib);
    assert!(hit.contains(&"narrowing-cast"), "{hit:?}");
    // Integration-test sources are exempt by design.
    assert!(rules_hit("tests/sample.rs", src, SourceKind::Tests).is_empty());
}

#[test]
fn narrowing_cast_skips_cfg_test_regions_and_checked_conversions() {
    let src = r#"
pub fn wave(live: usize) -> Vec<i32> {
    (0..i32::try_from(live).expect("fits")).collect()
}
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let e = 5usize;
        assert_eq!(e as i32, 5);
    }
}
"#;
    let fr = lint_source("src/sample.rs", src, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
}

// ---- determinism: the PR 9 class -----------------------------------

#[test]
fn determinism_catches_wallclock_and_hash_iteration_in_report_modules() {
    // The pre-PR 9 shape: wallclock and hash-iteration feeding the
    // SLO report, breaking byte-identity between identical runs.
    let src = r#"
use std::collections::HashMap;
use std::time::Instant;
pub fn report() -> String {
    let t = Instant::now();
    let m: HashMap<String, u64> = HashMap::new();
    let mut s = String::new();
    for (k, v) in &m {
        s.push_str(k);
        let _ = v;
    }
    let _ = t;
    s
}
"#;
    let hit = rules_hit("src/runtime/server.rs", src, SourceKind::Lib);
    assert!(hit.iter().filter(|r| **r == "determinism").count() >= 2, "{hit:?}");
    // Same tokens outside the report-rendering modules are fine.
    assert!(rules_hit("src/sched/other.rs", src, SourceKind::Lib).is_empty());
}

// ---- atomic-justify: the frontier-CAS audit ------------------------

#[test]
fn atomic_justify_requires_ordering_rationale() {
    // The frontier claim-CAS shape, minus its rationale comment.
    let bare = r#"
use std::sync::atomic::{AtomicBool, Ordering};
pub fn try_claim(f: &AtomicBool) -> bool {
    f.compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
        .is_ok()
}
"#;
    let hit = rules_hit("src/sample.rs", bare, SourceKind::Lib);
    assert!(hit.contains(&"atomic-justify"), "{hit:?}");

    let justified = r#"
use std::sync::atomic::{AtomicBool, Ordering};
pub fn try_claim(f: &AtomicBool) -> bool {
    // ordering: membership token only; no data published through it.
    f.compare_exchange(false, true, Ordering::Relaxed, Ordering::Relaxed)
        .is_ok()
}
"#;
    let fr = lint_source("src/sample.rs", justified, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
}

// ---- safety-comment: the SendPtr machinery -------------------------

#[test]
fn safety_comment_required_on_blocks_and_impls() {
    let bare = r#"
pub struct SendPtr<T>(pub *mut T);
unsafe impl<T> Send for SendPtr<T> {}
pub fn read(p: *const u32) -> u32 {
    unsafe { *p }
}
"#;
    let hit = rules_hit("src/sample.rs", bare, SourceKind::Lib);
    assert!(hit.iter().filter(|r| **r == "safety-comment").count() == 2, "{hit:?}");

    let annotated = r#"
pub struct SendPtr<T>(pub *mut T);
// SAFETY: only smuggles the address; dereferences happen at call
// sites that guarantee disjoint writes and join-before-read.
unsafe impl<T> Send for SendPtr<T> {}
pub fn read(p: *const u32) -> u32 {
    // SAFETY: caller guarantees p is valid and unaliased.
    unsafe { *p }
}
"#;
    let fr = lint_source("src/sample.rs", annotated, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
}

// ---- waivers -------------------------------------------------------

#[test]
fn waiver_with_reason_is_counted_not_silent() {
    let src = r#"
pub fn fold(e: i32) -> u64 {
    // lint:allow(narrowing-cast): same-width bit reinterpretation
    (e as u32 as u64) ^ 7
}
"#;
    let fr = lint_source("src/sample.rs", src, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
    assert_eq!(fr.waived.len(), 1);
    assert!(fr.waived[0].1.contains("bit reinterpretation"));
}

#[test]
fn reasonless_and_unused_waivers_are_violations() {
    let src = r#"
pub fn fold(e: i32) -> u64 {
    // lint:allow(narrowing-cast)
    (e as u32 as u64) ^ 7
}
// lint:allow(float-ord): nothing here sorts floats
pub fn noop() {}
"#;
    let hit = rules_hit("src/sample.rs", src, SourceKind::Lib);
    assert!(hit.contains(&"narrowing-cast"), "{hit:?}");
    assert!(hit.iter().filter(|r| **r == "waiver").count() == 2, "{hit:?}");
}

// ---- stripping edge cases ------------------------------------------

#[test]
fn stripping_survives_raw_strings_and_nested_comments() {
    // Patterns inside raw strings, nested block comments, and char
    // literals must not fire rules or fake waivers.
    let src = r#"
pub fn emit() -> (&'static str, char) {
    /* outer /* e as i32 */ still comment */
    let s = r"x as i32; Ordering::Relaxed; unsafe";
    let c = '"';
    (s, c)
}
"#;
    let fr = lint_source("src/sample.rs", src, SourceKind::Lib);
    assert!(fr.violations.is_empty(), "{:?}", fr.violations);
}
