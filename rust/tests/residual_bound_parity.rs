//! Differential harness for the bound-guided residual refresh
//! (`--residual-refresh bounded`) vs the exact dirty-list recompute,
//! across every scheduler (lbp, rbp, rs, rnbp + serial srbp) on small
//! Ising/Potts/chain instances.
//!
//! What is provable, and asserted here:
//!
//! * **Bound soundness** — at every step-3 refresh point (audited via
//!   `RunObserver`), each edge's maintained upper bound
//!   `res + slack (+ cushion)` dominates the true residual recomputed
//!   from scratch on the current messages. Runs use
//!   `belief_refresh_every = 0` (untracked beliefs) so the run's engine
//!   and the auditing reference perform identical arithmetic — the only
//!   allowance is `SLACK_CUSHION`, covering the re-association jitter of
//!   recomputing an edge whose reverse message committed.
//! * **Trajectory identity where provable** — strictly ε-filtered
//!   top-k schedulers (rbp, rnbp) only commit rows with `δ ≥ eps`, so
//!   every dependent's slack lands at `≥ SLACK_PER_DELTA·eps` and the
//!   bound filter never fires: `bounded` reproduces `exact` bit for bit
//!   (equal digests, iterate counts, bitwise marginals) with zero
//!   skips. Schedulers that commit *sub-ε* rows (lbp: every changed
//!   message; rs: splash-tree edges) genuinely skip — their waves then
//!   commit ε-stale cached candidates (slack carried over) where
//!   `exact` commits freshly refreshed ones, so for lbp/rs the asserted
//!   contract is the robust one: both modes converge to the same fixed
//!   point within 1e-3.
//! * **Convergence honesty** — a run never stops `Converged` while a
//!   full recompute finds a residual at or above eps (beyond the
//!   documented jitter cushion).
//! * **Work reduction** — on narrow-frontier and all-message workloads
//!   the bounded refresh issues strictly fewer engine-call rows.
//!
//! The engine matrix honors `BP_TEST_ENGINE` (`native` / `parallel`),
//! which CI loops over so engine-conditional regressions cannot slip
//! through on one engine only; unset, both engines run.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

mod common;

use bp_sched::coordinator::{
    run_observed, ResidualRefresh, RunParams, RunResult, SessionBuilder, StopReason,
};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::sched::{srbp, Lbp, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;
use common::{assert_bits_equal, engines_under_test, BoundAuditor};

const GPU_SCHEDULERS: [&str; 4] = ["lbp", "rbp", "rs", "rnbp"];

fn test_graphs() -> Vec<(&'static str, Mrf)> {
    let mut rng = Rng::new(20_260_729);
    vec![
        (
            "ising6",
            DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap(),
        ),
        (
            "potts5_q3",
            DatasetSpec::Potts { n: 5, q: 3, c: 1.0 }.generate(&mut rng).unwrap(),
        ),
        (
            "chain40",
            DatasetSpec::Chain { n: 40, c: 5.0 }.generate(&mut rng).unwrap(),
        ),
    ]
}

fn mk_sched(name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(0.25)),
        "rs" => Box::new(ResidualSplash::new(0.25, 2)),
        "rnbp" => Box::new(Rnbp::synthetic(0.7, 11)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    match name {
        "native" => Box::new(NativeEngine::new()),
        "parallel" => Box::new(ParallelEngine::with_threads(4)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(mode: ResidualRefresh) -> RunParams {
    RunParams {
        want_marginals: true,
        timeout: 30.0,
        // untracked beliefs: every engine read re-derives from the
        // current messages, bit-identical to the auditor's reference
        // recompute — bound soundness needs no drift allowance
        belief_refresh_every: 0,
        residual_refresh: mode,
        ..Default::default()
    }
}

fn run_one(g: &Mrf, sched: &str, engine: &str, mode: ResidualRefresh) -> RunResult {
    // through the owning Session API (of which `run` is the shim)
    let mut session = SessionBuilder::new(g.clone(), mk_engine(engine), mk_sched(sched))
        .with_params(params(mode))
        .build()
        .unwrap();
    session.solve().unwrap();
    session.into_result().unwrap()
}

#[test]
fn bounds_dominate_true_residuals_at_every_refresh() {
    for (glabel, g) in &test_graphs() {
        for sched in GPU_SCHEDULERS {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine} bounded");
                let mut eng = mk_engine(engine);
                let mut s = mk_sched(sched);
                let mut auditor = BoundAuditor::new(what.clone(), NativeEngine::new());
                let r = run_observed(
                    g,
                    eng.as_mut(),
                    s.as_mut(),
                    &params(ResidualRefresh::Bounded),
                    &mut auditor,
                )
                .unwrap();
                assert!(auditor.audits > 1, "{what}: auditor never ran");
                assert_eq!(r.stop, StopReason::Converged, "{what}");
            }
        }
    }
}

#[test]
fn bounded_and_exact_select_identical_frontiers_and_fixed_points() {
    for (glabel, g) in &test_graphs() {
        for sched in GPU_SCHEDULERS {
            for engine in engines_under_test() {
                let what = format!("{glabel}/{sched}/{engine}");
                let exact = run_one(g, sched, engine, ResidualRefresh::Exact);
                let bounded = run_one(g, sched, engine, ResidualRefresh::Bounded);
                assert_eq!(exact.stop, StopReason::Converged, "{what}: exact");
                assert_eq!(bounded.stop, StopReason::Converged, "{what}: bounded");
                assert_eq!(exact.refresh_skipped, 0, "{what}: exact must never skip");
                // every scheduler: same fixed point within the paper's
                // marginal tolerance
                for (i, (x, y)) in exact
                    .marginals
                    .as_ref()
                    .unwrap()
                    .iter()
                    .zip(bounded.marginals.as_ref().unwrap())
                    .enumerate()
                {
                    assert!((x - y).abs() < 1e-3, "{what}: marginal[{i}] {x} vs {y}");
                }
                if sched == "lbp" {
                    // lbp never needs a mid-wave recompute (its wave is
                    // committed from cache); ε-stale edges must not
                    // smuggle one in, or bounded mode would trade the
                    // refresh saving for full-frontier engine rows.
                    assert_eq!(
                        bounded.phases.get("update"),
                        0.0,
                        "{what}: ε-stale edges forced mid-wave recomputes"
                    );
                }
                if sched == "rs" || sched == "lbp" {
                    // sub-ε committers: their waves commit ε-stale
                    // cached candidates where exact commits refreshed
                    // ones (module docs) — trajectory identity is not
                    // a theorem here, only fixed-point agreement,
                    // asserted above.
                    continue;
                }
                // strictly ε-filtered schedulers never skip (all commit
                // deltas are >= eps), so bounded must reproduce exact
                // bit for bit at zero cost
                assert_eq!(bounded.refresh_skipped, 0, "{what}: deltas are >= eps");
                assert_eq!(
                    exact.frontier_digest, bounded.frontier_digest,
                    "{what}: the refresh modes selected different frontiers"
                );
                assert_eq!(exact.iterations, bounded.iterations, "{what}");
                assert_eq!(exact.message_updates, bounded.message_updates, "{what}");
                assert_eq!(
                    exact.refresh_rows, bounded.refresh_rows,
                    "{what}: refresh work must be identical when nothing skips"
                );
                assert_bits_equal(
                    exact.marginals.as_ref().unwrap(),
                    bounded.marginals.as_ref().unwrap(),
                    &format!("{what}: marginals"),
                );
            }
        }
    }
}

#[test]
fn bounded_skips_rows_on_narrow_frontier_and_all_message_workloads() {
    // lbp commits every changed edge, so near-converged regions receive
    // a stream of tiny-delta commits whose dependents the bound filter
    // provably skips; rs grows splash trees through converged regions
    // with the same effect. Both must show strictly fewer refresh rows.
    let mut rng = Rng::new(31);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();
    let policies: [(&str, fn() -> Box<dyn Scheduler>); 2] = [
        ("lbp", || Box::new(Lbp::new())),
        ("rs", || Box::new(ResidualSplash::new(1.0 / 16.0, 2))),
    ];
    for (label, mk) in policies {
        let run_mode = |mode: ResidualRefresh| -> RunResult {
            let mut session =
                SessionBuilder::new(g.clone(), Box::new(NativeEngine::new()), mk())
                    .with_params(params(mode))
                    .build()
                    .unwrap();
            session.solve().unwrap();
            session.into_result().unwrap()
        };
        let exact = run_mode(ResidualRefresh::Exact);
        let bounded = run_mode(ResidualRefresh::Bounded);
        assert!(exact.converged() && bounded.converged(), "{label}");
        assert!(bounded.refresh_skipped > 0, "{label}: bound filter never engaged");
        assert!(
            bounded.refresh_rows < exact.refresh_rows,
            "{label}: bounded {} rows vs exact {} rows — no work saved",
            bounded.refresh_rows,
            exact.refresh_rows
        );
    }
}

#[test]
fn srbp_is_residual_refresh_invariant_and_agrees_at_fixed_point() {
    // The serial baseline has no dirty-list refresh: the knob must not
    // change a single bit of its trajectory, and its fixed point must
    // agree with the coordinator's (both modes) within the usual 1e-3.
    let mut rng = Rng::new(99);
    let g = DatasetSpec::Ising { n: 6, c: 1.5 }.generate(&mut rng).unwrap();
    let a = srbp::run_serial(&g, &params(ResidualRefresh::Exact)).unwrap();
    let b = srbp::run_serial(&g, &params(ResidualRefresh::Bounded)).unwrap();
    assert_eq!(a.stop, b.stop);
    assert_eq!(a.message_updates, b.message_updates);
    assert_eq!(a.frontier_digest, b.frontier_digest);
    assert_eq!(a.refresh_rows, 0);
    assert_eq!(a.refresh_skipped, 0);
    assert_bits_equal(
        a.marginals.as_ref().unwrap(),
        b.marginals.as_ref().unwrap(),
        "srbp marginals",
    );
    for engine in engines_under_test() {
        let coord = run_one(&g, "lbp", engine, ResidualRefresh::Bounded);
        assert!(coord.converged());
        for (i, (x, y)) in a
            .marginals
            .as_ref()
            .unwrap()
            .iter()
            .zip(coord.marginals.as_ref().unwrap())
            .enumerate()
        {
            assert!(
                (x - y).abs() < 1e-3,
                "srbp vs lbp/{engine} marginal[{i}]: {x} vs {y}"
            );
        }
    }
}
