//! Integration harness for the multi-tenant serving runtime
//! (`bp_sched::runtime::server`), over the `BP_TEST_ENGINE` matrix:
//!
//! * **request conservation** — every offered request gets exactly one
//!   response, ids dense, served + rejected == offered, globally and
//!   per tenant;
//! * **per-tenant budget enforcement** — a starved simulated-device
//!   budget and a 1-iteration cap each degrade *their* tenant's
//!   responses (stale labels, capped iteration counts) while a generous
//!   tenant converges, inside one shared trace;
//! * **staleness honesty** — stale labels appear exactly on
//!   unconverged serves and carry a residual bound at or above the
//!   tenant's ε;
//! * **bitwise replay parity** — at one worker and a deep queue, every
//!   served response's marginals/iterations/rows bitwise-match a serial
//!   warm [`bp_sched::coordinator::Session`] replaying the same
//!   admitted evidence sequence;
//! * **report determinism** — two same-seed runs render byte-identical
//!   JSON even at several workers.

mod common;

use bp_sched::config::{EngineKind, ServerConfig};
use bp_sched::coordinator::campaign::EvidenceStream;
use bp_sched::coordinator::{RunParams, SessionBuilder};
use bp_sched::datasets::DatasetSpec;
use bp_sched::engine::belief::DEFAULT_REFRESH_EVERY;
use bp_sched::engine::native::NativeEngine;
use bp_sched::engine::parallel::ParallelEngine;
use bp_sched::engine::{MessageEngine, UpdateOptions};
use bp_sched::runtime::server::{
    self, Outcome, QueryBudget, Request, SchedSpec, ServeOptions, Staleness, TenantSpec,
};
use bp_sched::util::Rng;

use common::{assert_bits_equal, engines_under_test};

fn kind_of(name: &str) -> EngineKind {
    match name {
        "native" => EngineKind::Native,
        "parallel" => EngineKind::Parallel,
        other => panic!("unexpected engine under test {other:?}"),
    }
}

fn opts(engine: EngineKind) -> ServeOptions {
    ServeOptions {
        workers: 2,
        queue_depth: 4,
        engine,
        engine_threads: 2,
        update: UpdateOptions::default(),
        sched: SchedSpec::Rbp { p: 0.25 },
        residual_refresh: Default::default(),
        belief_refresh_every: DEFAULT_REFRESH_EVERY,
        prewarm: true,
        keep_marginals: false,
    }
}

fn make_tenants(budgets: &[QueryBudget], seed: u64) -> Vec<TenantSpec> {
    budgets
        .iter()
        .enumerate()
        .map(|(t, &budget)| {
            let spec = match t % 3 {
                0 => DatasetSpec::Ising { n: 4, c: 1.5 },
                1 => DatasetSpec::Potts { n: 4, q: 3, c: 1.0 },
                _ => DatasetSpec::Ising { n: 5, c: 1.0 },
            };
            let mut rng = Rng::new(seed ^ (t as u64).wrapping_mul(0x9E37_79B9));
            TenantSpec {
                id: t,
                graph: spec.generate(&mut rng).unwrap(),
                budget,
                evidence_seed: seed.wrapping_add(100 + t as u64),
            }
        })
        .collect()
}

fn make_engine(kind: EngineKind, threads: usize) -> Box<dyn MessageEngine> {
    match kind {
        EngineKind::Native => Box::new(NativeEngine::with_options(UpdateOptions::default())),
        EngineKind::Parallel => {
            Box::new(ParallelEngine::with_options_threads(UpdateOptions::default(), threads))
        }
        EngineKind::Pjrt => unreachable!("the server rejects pjrt"),
    }
}

#[test]
fn conserves_requests_globally_and_per_tenant() {
    for eng in engines_under_test() {
        let cfg = ServerConfig {
            tenants: 3,
            workers: 2,
            queue_depth: 2,
            requests: 24,
            arrival_rate: 3_000.0,
            seed: 11,
            n: 4,
            engine: kind_of(eng),
            engine_threads: 2,
            sim_budget: 1e-3,
            workload: "mixed".into(),
            ..ServerConfig::default()
        };
        let report = server::run_server(&cfg).unwrap();
        assert!(report.conserves(cfg.requests), "{eng}: conservation");
        let sum_offered: usize = report.per_tenant.iter().map(|(_, s)| s.offered).sum();
        assert_eq!(sum_offered, cfg.requests, "{eng}: tenants partition the trace");
        for (t, s) in &report.per_tenant {
            assert_eq!(s.served + s.rejected, s.offered, "{eng}: tenant {t} conservation");
            assert!(s.stale_served <= s.served, "{eng}: tenant {t} staleness bound");
        }
    }
}

#[test]
fn per_tenant_budgets_enforced_with_honest_staleness_labels() {
    for eng in engines_under_test() {
        // Three tenants under one trace, three budget regimes.
        let starved = QueryBudget {
            eps: 1e-7,
            max_iterations: 50_000,
            sim_budget: 1e-12,
            timeout: 30.0,
        };
        let generous = QueryBudget {
            eps: 1e-4,
            max_iterations: 200_000,
            sim_budget: 10.0,
            timeout: 30.0,
        };
        let capped = QueryBudget {
            eps: 1e-7,
            max_iterations: 1,
            sim_budget: 10.0,
            timeout: 30.0,
        };
        let tenants = make_tenants(&[starved, generous, capped], 5);
        // Arrivals 0.1 virtual seconds apart: far beyond any service
        // time here, so admission never interferes with this test.
        let requests: Vec<Request> = (0..12)
            .map(|id| Request {
                id,
                tenant: id % 3,
                arrival: 0.1 * id as f64,
                flips: 2,
                amplitude: 2.5,
            })
            .collect();
        let report = server::serve(tenants, &requests, &opts(kind_of(eng))).unwrap();
        assert!(report.conserves(requests.len()));
        assert_eq!(report.global.rejected, 0, "{eng}: spaced arrivals must all admit");
        for r in &report.responses {
            match &r.outcome {
                Outcome::Served { staleness, iterations, .. } => match r.tenant {
                    0 => match staleness {
                        Staleness::Stale { residual_ub } => assert!(
                            *residual_ub >= starved.eps,
                            "{eng}: request {} stopped stale but sub-eps ({residual_ub})",
                            r.id
                        ),
                        Staleness::Converged => panic!(
                            "{eng}: request {} converged under a ~zero device budget",
                            r.id
                        ),
                    },
                    1 => assert_eq!(
                        *staleness,
                        Staleness::Converged,
                        "{eng}: generous tenant must converge (request {})",
                        r.id
                    ),
                    _ => {
                        assert!(
                            *iterations <= capped.max_iterations,
                            "{eng}: request {} ran {iterations} iterations past its cap",
                            r.id
                        );
                        assert!(
                            matches!(staleness, Staleness::Stale { .. }),
                            "{eng}: a 1-iteration cap at eps=1e-7 cannot converge (request {})",
                            r.id
                        );
                    }
                },
                Outcome::Rejected(_) => panic!("{eng}: request {} rejected", r.id),
            }
        }
        // Degradation shows up in the right per-tenant rows.
        assert_eq!(report.per_tenant[0].1.stale_served, report.per_tenant[0].1.served);
        assert_eq!(report.per_tenant[1].1.stale_served, 0);
        assert_eq!(report.per_tenant[2].1.stale_served, report.per_tenant[2].1.served);
    }
}

#[test]
fn one_worker_serving_matches_serial_session_replay_bitwise() {
    for eng in engines_under_test() {
        let kind = kind_of(eng);
        let budget = QueryBudget {
            eps: 1e-4,
            max_iterations: 100_000,
            sim_budget: 10.0,
            timeout: 30.0,
        };
        let tenants = make_tenants(&[budget, budget], 42);
        // Mixed minor/major evidence, interleaved tenants, sorted
        // arrivals; deep queue so every request is admitted and the
        // tenant's admitted sequence is the full per-tenant trace.
        let requests: Vec<Request> = (0..10)
            .map(|id| {
                let (flips, amplitude) = if id % 3 == 0 { (3, 2.0) } else { (1, 1.0) };
                Request { id, tenant: id % 2, arrival: 0.05 * id as f64, flips, amplitude }
            })
            .collect();
        let serve_opts = ServeOptions {
            workers: 1,
            queue_depth: requests.len(),
            keep_marginals: true,
            ..opts(kind)
        };
        let report = server::serve(tenants.clone(), &requests, &serve_opts).unwrap();
        assert!(report.conserves(requests.len()));
        assert_eq!(report.global.rejected, 0, "{eng}: deep queue must admit everything");

        for spec in &tenants {
            let params = RunParams {
                eps: spec.budget.eps,
                max_iterations: spec.budget.max_iterations,
                timeout: spec.budget.timeout,
                sim_timeout: spec.budget.sim_budget,
                want_marginals: true,
                ..RunParams::default()
            };
            let mut session = SessionBuilder::new(
                spec.graph.clone(),
                make_engine(kind, serve_opts.engine_threads),
                serve_opts.sched.build(),
            )
            .with_params(params)
            .build()
            .unwrap();
            session.solve().unwrap(); // prewarm, as the worker does
            let mut stream = EvidenceStream::new(spec.evidence_seed, 1, 1.0);
            for req in requests.iter().filter(|r| r.tenant == spec.id) {
                let batch = stream.next_batch_with(session.graph(), req.flips, req.amplitude);
                let refs: Vec<(usize, &[f32])> =
                    batch.iter().map(|(v, row)| (*v, row.as_slice())).collect();
                session.apply_evidence(&refs).unwrap();
                let res = session.solve().unwrap();
                let what = format!("{eng}: tenant {} request {}", spec.id, req.id);
                match &report.responses[req.id].outcome {
                    Outcome::Served { staleness, iterations, rows, marginals, .. } => {
                        assert_eq!(*iterations, res.iterations, "{what}: iterations");
                        assert_eq!(*rows, res.update_rows(), "{what}: rows");
                        assert_eq!(
                            matches!(staleness, Staleness::Converged),
                            res.converged(),
                            "{what}: staleness label vs replay convergence"
                        );
                        assert_bits_equal(
                            marginals.as_ref().expect("keep_marginals retains them"),
                            res.marginals.as_ref().expect("want_marginals computes them"),
                            &what,
                        );
                    }
                    Outcome::Rejected(_) => panic!("{what}: rejected under a deep queue"),
                }
            }
        }
    }
}

#[test]
fn slo_report_json_is_deterministic_across_runs() {
    for eng in engines_under_test() {
        let cfg = ServerConfig {
            tenants: 4,
            workers: 3,
            queue_depth: 2,
            requests: 32,
            arrival_rate: 5_000.0,
            seed: 99,
            n: 4,
            engine: kind_of(eng),
            engine_threads: 2,
            sim_budget: 2e-3,
            workload: "mixed".into(),
            ..ServerConfig::default()
        };
        let a = server::run_server(&cfg).unwrap().to_json().render();
        let b = server::run_server(&cfg).unwrap().to_json().render();
        assert_eq!(a, b, "{eng}: same seed must render byte-identical SLO reports");
    }
}
