//! Failure-injection tests: the runtime must fail loudly and descriptively
//! on corrupted artifacts, never silently compute with a mismatched
//! manifest.

use bp_sched::engine::Semiring;
use bp_sched::runtime::{Manifest, Runtime};

fn artifacts_ready() -> bool {
    bp_sched::runtime::default_artifacts_dir()
        .join("manifest.txt")
        .exists()
}

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("bpfail_{tag}_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn missing_manifest_is_descriptive() {
    let dir = tmp_dir("nomanifest");
    let err = match Runtime::new(&dir) {
        Err(e) => e.to_string(),
        Ok(_) => panic!("expected failure"),
    };
    assert!(err.contains("make artifacts"), "unhelpful error: {err}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn manifest_with_missing_artifact_file_fails_on_use() {
    let dir = tmp_dir("missingfile");
    std::fs::write(
        dir.join("manifest.txt"),
        "version=2\nfingerprint=abc\nconfig name=ghost V=10 M=20 A=2 D=2 buckets=512\n",
    )
    .unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    let msg = match rt.candidate_executable("ghost", 512, Semiring::SumProduct) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected failure"),
    };
    assert!(msg.contains("ghost"), "error should name the artifact: {msg}");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn corrupted_hlo_text_fails_to_parse() {
    let dir = tmp_dir("corrupt");
    std::fs::write(
        dir.join("manifest.txt"),
        "version=2\nfingerprint=abc\nconfig name=bad V=10 M=20 A=2 D=2 buckets=512\n",
    )
    .unwrap();
    std::fs::create_dir_all(dir.join("bad")).unwrap();
    std::fs::write(dir.join("bad/cand_sp_k512.hlo.txt"), "this is not HLO {").unwrap();
    let mut rt = Runtime::new(&dir).unwrap();
    assert!(rt.candidate_executable("bad", 512, Semiring::SumProduct).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn unknown_bucket_rejected() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::from_default_dir().unwrap();
    let msg = match rt.candidate_executable("ising10", 999, Semiring::SumProduct) {
        Err(e) => format!("{e:#}"),
        Ok(_) => panic!("expected failure"),
    };
    assert!(msg.contains("bucket"));
}

#[test]
fn warmup_compiles_every_bucket() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let mut rt = Runtime::from_default_dir().unwrap();
    assert_eq!(rt.compiled_count(), 0);
    rt.warmup("ising10").unwrap();
    let expect = rt.class("ising10").unwrap().buckets.len() + 1;
    assert_eq!(rt.compiled_count(), expect);
    // idempotent
    rt.warmup("ising10").unwrap();
    assert_eq!(rt.compiled_count(), expect);
}

#[test]
fn frontier_larger_than_largest_bucket_rejected() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use bp_sched::datasets::DatasetSpec;
    use bp_sched::engine::{pjrt::PjrtEngine, MessageEngine};
    use bp_sched::util::Rng;
    let mut rng = Rng::new(1);
    let g = DatasetSpec::Ising { n: 10, c: 2.0 }.generate(&mut rng).unwrap();
    let mut eng = PjrtEngine::from_default_dir().unwrap();
    let logm = g.uniform_messages();
    let oversized: Vec<i32> = vec![0; 10_000]; // > largest ising10 bucket
    let err = eng.candidates(&g, logm.as_slice(), &oversized).unwrap_err();
    assert!(format!("{err:#}").contains("exceeds"));
}

#[test]
fn manifest_rejects_manifest_mismatched_class() {
    // A graph generated for a class absent from the manifest errors out.
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use bp_sched::datasets::chain;
    use bp_sched::engine::{pjrt::PjrtEngine, MessageEngine};
    use bp_sched::util::Rng;
    let mut rng = Rng::new(2);
    let g = chain::generate("chain999", 100, 10.0, &mut rng).unwrap();
    let mut eng = PjrtEngine::from_default_dir().unwrap();
    let logm = g.uniform_messages();
    let err = eng
        .candidates(&g, logm.as_slice(), &[0, 1, 2])
        .unwrap_err();
    assert!(format!("{err:#}").contains("chain999"));
}

#[test]
fn manifest_fingerprint_exposed() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let m = Manifest::load(bp_sched::runtime::default_artifacts_dir()).unwrap();
    assert!(!m.fingerprint.is_empty());
}
