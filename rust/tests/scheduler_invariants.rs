//! Property-style scheduler/coordinator invariants over randomized
//! inputs (seeded, many cases — the vendored build has no proptest, so
//! the generators live here).

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

use bp_sched::coordinator::{run, RunParams, StopReason};
use bp_sched::datasets::{ising, protein, DatasetSpec};
use bp_sched::engine::native::NativeEngine;
use bp_sched::perfmodel::SelectKind;
use bp_sched::sched::{Lbp, Rbp, ResidualSplash, Rnbp, SchedContext, Scheduler};
use bp_sched::util::Rng;
use bp_sched::Mrf;

fn random_residuals(rng: &mut Rng, g: &Mrf, frac_hot: f64) -> Vec<f32> {
    let mut res = vec![0.0f32; g.num_edges];
    for e in 0..g.live_edges {
        if rng.coin(frac_hot) {
            res[e] = rng.uniform() as f32 + 1e-3;
        }
    }
    res
}

fn ctx<'a>(g: &'a Mrf, res: &'a [f32], eps: f32, iteration: usize) -> SchedContext<'a> {
    let unconverged = res[..g.live_edges].iter().filter(|&&r| r >= eps).count();
    SchedContext {
        mrf: g,
        residuals: res,
        eps,
        iteration,
        unconverged,
        prev_unconverged: unconverged,
    }
}

/// Every scheduler returns only live, in-range frontier edges, without
/// duplicates inside a wave.
#[test]
fn frontier_edges_always_live_and_unique_within_wave() {
    let mut rng = Rng::new(42);
    for case in 0..25 {
        let n = 4 + rng.below(6);
        let c = 1.0 + rng.uniform() * 2.0;
        let g = ising::generate("i", n, c, &mut rng).unwrap();
        let frac = 0.3 + 0.5 * rng.uniform();
        let res = random_residuals(&mut rng, &g, frac);
        let mut policies: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Lbp::new()),
            Box::new(Rbp::new(0.25)),
            Box::new(ResidualSplash::new(0.25, 1 + rng.below(3))),
            Box::new(Rnbp::new(0.3, 0.9, case as u64)),
        ];
        for s in policies.iter_mut() {
            let c = ctx(&g, &res, 1e-4, case);
            let waves = s.select(&c);
            for wave in &waves {
                let mut seen = std::collections::HashSet::new();
                for &e in wave {
                    assert!(e >= 0, "{}: negative edge", s.name());
                    assert!((e as usize) < g.live_edges, "{}: dead edge", s.name());
                    assert!(seen.insert(e), "{}: duplicate edge in wave", s.name());
                }
            }
        }
    }
}

/// Single-wave schedulers only pick unconverged edges (the eps-filter).
#[test]
fn eps_filter_respected_by_rbp_and_rnbp() {
    let mut rng = Rng::new(7);
    for case in 0..20 {
        let n = 5 + rng.below(5);
        let g = ising::generate("i", n, 2.0, &mut rng).unwrap();
        let res = random_residuals(&mut rng, &g, 0.4);
        let mut policies: Vec<Box<dyn Scheduler>> = vec![
            Box::new(Rbp::new(0.5)),
            Box::new(Rnbp::new(0.5, 0.9, case as u64)),
        ];
        for s in policies.iter_mut() {
            let c = ctx(&g, &res, 1e-4, 1);
            for wave in s.select(&c) {
                for &e in &wave {
                    assert!(
                        res[e as usize] >= 1e-4,
                        "{} picked converged edge {e}",
                        s.name()
                    );
                }
            }
        }
    }
}

/// RBP frontier size is exactly min(k, #unconverged).
#[test]
fn rbp_frontier_size_law() {
    let mut rng = Rng::new(11);
    for _ in 0..20 {
        let g = ising::generate("i", 6, 2.0, &mut rng).unwrap();
        let res = random_residuals(&mut rng, &g, 0.6);
        let hot = res[..g.live_edges].iter().filter(|&&r| r >= 1e-4).count();
        if hot == 0 {
            continue;
        }
        let p = 0.1 + 0.4 * rng.uniform();
        let mut s = Rbp::new(p);
        let waves = s.select(&ctx(&g, &res, 1e-4, 0));
        let k = ((p * g.live_edges as f64).ceil() as usize).min(hot);
        assert_eq!(waves[0].len(), k);
    }
}

/// Stop-reason semantics: converged means the maintained residual state
/// is below eps.
#[test]
fn converged_implies_residuals_below_eps() {
    let mut rng = Rng::new(13);
    for case in 0..6usize {
        let g = ising::generate("i", 5 + case, 1.5 + 0.3 * case as f64, &mut rng).unwrap();
        let params = RunParams {
            max_iterations: 50 + 10 * case,
            eps: 1e-4,
            cost_model: None,
            ..Default::default()
        };
        let mut eng = NativeEngine::new();
        let mut sched = Rnbp::new(0.4, 0.8, case as u64);
        let r = run(&g, &mut eng, &mut sched, &params).unwrap();
        match r.stop {
            StopReason::Converged => assert!(r.final_residual < params.eps),
            _ => assert!(r.final_residual >= 0.0),
        }
    }
}

/// Fixed point is schedule-independent: all policies land on the same
/// marginals on an easy graph.
#[test]
fn fixed_point_independent_of_schedule() {
    let mut rng = Rng::new(17);
    let g = ising::generate("i", 6, 1.2, &mut rng).unwrap();
    let params = RunParams {
        eps: 1e-6,
        want_marginals: true,
        cost_model: None,
        ..Default::default()
    };
    let mut results = Vec::new();
    let mut policies: Vec<Box<dyn Scheduler>> = vec![
        Box::new(Lbp::new()),
        Box::new(Rbp::new(0.3)),
        Box::new(ResidualSplash::new(0.3, 2)),
        Box::new(Rnbp::new(0.5, 1.0, 3)),
    ];
    for s in policies.iter_mut() {
        let mut eng = NativeEngine::new();
        let r = run(&g, &mut eng, s.as_mut(), &params).unwrap();
        assert!(r.converged(), "{} failed on easy graph", r.scheduler);
        results.push(r.marginals.unwrap());
    }
    for other in &results[1..] {
        for (a, b) in results[0].iter().zip(other) {
            assert!((a - b).abs() < 5e-3, "marginal mismatch {a} vs {b}");
        }
    }
}

/// Work per iteration scales with p (the parallelism knob actually
/// controls the frontier budget).
#[test]
fn parallelism_controls_work_per_iteration() {
    let mut rng = Rng::new(19);
    let g = ising::generate("i", 12, 2.0, &mut rng).unwrap();
    let res = vec![1.0f32; g.num_edges];
    for (lo, hi) in [(0.05, 0.5), (0.1, 0.8)] {
        let mut a = Rbp::new(lo);
        let mut b = Rbp::new(hi);
        let na: usize = a.select(&ctx(&g, &res, 1e-4, 0)).iter().map(|w| w.len()).sum();
        let nb: usize = b.select(&ctx(&g, &res, 1e-4, 0)).iter().map(|w| w.len()).sum();
        assert!(nb > na * 2, "p={hi} gave {nb}, p={lo} gave {na}");
    }
}

/// Select kinds map to the cost model correctly.
#[test]
fn scheduler_kinds() {
    assert_eq!(Lbp::new().kind(), SelectKind::All);
    assert_eq!(Rbp::new(0.5).kind(), SelectKind::SortTopK);
    assert_eq!(ResidualSplash::new(0.5, 2).kind(), SelectKind::VertexSortSplash);
    assert_eq!(Rnbp::new(0.5, 1.0, 0).kind(), SelectKind::RandomFilter);
}

/// Protein graphs (variable arity, irregular) run through the whole
/// coordinator with the native engine.
#[test]
fn protein_native_coordinator_roundtrip() {
    let mut rng = Rng::new(23);
    let g = protein::generate("tight", &Default::default(), &mut rng).unwrap();
    let params = RunParams {
        timeout: 30.0,
        want_marginals: true,
        ..Default::default()
    };
    let mut eng = NativeEngine::new();
    let mut s = Rnbp::new(0.4, 0.9, 31);
    let r = run(&g, &mut eng, &mut s, &params).unwrap();
    assert!(r.converged(), "{:?}", r.stop);
    let m = r.marginals.unwrap();
    for v in 0..g.live_vertices {
        let av = g.arity_of(v);
        let total: f32 = m[v * g.max_arity..v * g.max_arity + av].iter().sum();
        assert!((total - 1.0).abs() < 1e-3, "vertex {v}: {total}");
    }
}

/// Campaign determinism: same seeds, same outcome counts.
#[test]
fn campaign_outcomes_deterministic() {
    let spec = DatasetSpec::Ising { n: 6, c: 2.0 };
    let run_once = || {
        let ds = spec.generate_many(3, 99).unwrap();
        let params = RunParams { cost_model: None, ..Default::default() };
        bp_sched::coordinator::campaign::run_campaign("x", &ds.graphs, 2, |i, g| {
            let mut eng = NativeEngine::new();
            let mut s = Rnbp::new(0.4, 1.0, i as u64);
            run(g, &mut eng, &mut s, &params)
        })
        .unwrap()
    };
    let (a, b) = (run_once(), run_once());
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.iterations, y.iterations);
        assert_eq!(x.message_updates, y.message_updates);
        assert_eq!(x.converged(), y.converged());
    }
}
