//! Differential harness for the stateful `Session` API
//! (`coordinator::SessionBuilder`): warm-started multi-query serving
//! must agree with cold runs on the mutated graph, and must be
//! strictly cheaper on small perturbations.
//!
//! What is asserted:
//!
//! * **Warm ≡ cold at fixed point** — random evidence-update streams
//!   (graphs from the shared `tests/common::random_mrf` generator, the
//!   same sampler the fuzz harness uses): after every warm `solve()`,
//!   a cold run on an identical mutated graph lands on the same fixed
//!   point (marginals at fixed-point tolerance), for all schedulers ×
//!   engines × refresh modes.
//! * **Warm is strictly cheaper** — after a single-vertex evidence
//!   flip on a narrow-frontier workload, the warm re-solve performs
//!   strictly fewer update rows (and iterations) than the cold solve.
//! * **Shim equivalence** — `run()` is a bit-for-bit shim over a
//!   single-use `Session`.
//! * **Evidence lifecycle** — `clear_evidence` restores the build-time
//!   unaries bitwise; invalid batches are rejected atomically;
//!   borrowed (shim) sessions refuse evidence.
//!
//! The engine matrix honors `BP_TEST_ENGINE` (`native` / `parallel`),
//! which CI loops over; unset, both engines run.

// One-shot harness code: the deprecated run()/run_observed() shims are
// exercised here on purpose (they are the kept-for-one-release API).
#![allow(deprecated)]

mod common;

use bp_sched::coordinator::campaign::EvidenceStream;
use bp_sched::coordinator::{
    run, ResidualRefresh, RunParams, RunResult, Session, SessionBuilder, StopReason,
};
use bp_sched::engine::{native::NativeEngine, parallel::ParallelEngine, MessageEngine};
use bp_sched::sched::{Lbp, Rbp, ResidualSplash, Rnbp, Scheduler};
use bp_sched::util::Rng;
use common::{assert_bits_equal, engines_under_test, random_mrf};

const MODES: [ResidualRefresh; 3] = [
    ResidualRefresh::Exact,
    ResidualRefresh::Bounded,
    ResidualRefresh::Lazy,
];

fn mk_sched(name: &str) -> Box<dyn Scheduler> {
    match name {
        "lbp" => Box::new(Lbp::new()),
        "rbp" => Box::new(Rbp::new(0.25)),
        "rs" => Box::new(ResidualSplash::new(0.25, 2)),
        "rnbp" => Box::new(Rnbp::synthetic(0.7, 19)),
        other => panic!("unknown scheduler {other}"),
    }
}

fn mk_engine(name: &str) -> Box<dyn MessageEngine> {
    match name {
        "native" => Box::new(NativeEngine::new()),
        "parallel" => Box::new(ParallelEngine::with_threads(4)),
        other => panic!("unknown engine {other}"),
    }
}

fn params(mode: ResidualRefresh) -> RunParams {
    RunParams {
        eps: 1e-5,
        // deterministic stop: iteration budget only
        max_iterations: 2_000,
        timeout: 1e9,
        cost_model: None,
        want_marginals: true,
        belief_refresh_every: 0,
        residual_refresh: mode,
        ..Default::default()
    }
}

fn apply(session: &mut Session, batch: &[(usize, Vec<f32>)]) {
    let updates: Vec<(usize, &[f32])> = batch.iter().map(|(v, r)| (*v, r.as_slice())).collect();
    session.apply_evidence(&updates).unwrap();
}

#[test]
fn warm_streams_match_cold_for_all_schedulers_and_engines() {
    let mut compared = 0usize;
    for seed in [5u64, 6, 7] {
        let mut rng = Rng::new(seed ^ 0x5e55_10a1);
        let (glabel, g) = random_mrf(&mut rng);
        for sched in ["lbp", "rbp", "rs", "rnbp"] {
            for engine in engines_under_test() {
                for mode in MODES {
                    let what = format!("{glabel}/{sched}/{engine}/{mode:?}");
                    let p = params(mode);
                    let mut warm =
                        SessionBuilder::new(g.clone(), mk_engine(engine), mk_sched(sched))
                            .with_params(p.clone())
                            .build()
                            .unwrap();
                    warm.solve().unwrap();
                    let mut stream = EvidenceStream::new(seed, 1, 0.6);
                    for _ in 0..3 {
                        let batch = stream.next_batch(warm.graph());
                        apply(&mut warm, &batch);
                        let warm_ok = warm.solve().unwrap().converged();
                        let cold = {
                            let mut eng = mk_engine(engine);
                            let mut s = mk_sched(sched);
                            run(warm.graph(), eng.as_mut(), s.as_mut(), &p).unwrap()
                        };
                        assert_ne!(cold.stop, StopReason::Stalled, "{what}");
                        if !(warm_ok && cold.converged()) {
                            continue;
                        }
                        compared += 1;
                        let mw = warm.marginals().unwrap();
                        for (i, (x, y)) in
                            mw.iter().zip(cold.marginals.as_ref().unwrap()).enumerate()
                        {
                            assert!(
                                (x - y).abs() < 1e-3,
                                "{what}: marginal[{i}] warm {x} vs cold {y}"
                            );
                        }
                    }
                }
            }
        }
    }
    assert!(
        compared >= 10,
        "only {compared} warm/cold fixed-point comparisons ran — workload too capped"
    );
}

#[test]
fn warm_resolve_is_strictly_cheaper_on_single_vertex_flip() {
    // The acceptance bar: a narrow-frontier workload, one evidence
    // flip, and the warm re-solve must pay strictly fewer update rows
    // (and iterations) than a cold solve on the mutated graph — for
    // the narrow-frontier schedulers and the full-frontier baseline
    // alike.
    let mut rng = Rng::new(2026);
    let g = bp_sched::datasets::DatasetSpec::Ising { n: 12, c: 1.5 }
        .generate(&mut rng)
        .unwrap();
    let flip_vertex = g.live_vertices / 2;
    let scheds: [(&str, fn() -> Box<dyn Scheduler>); 3] = [
        ("rs 1/16", || Box::new(ResidualSplash::new(1.0 / 16.0, 2))),
        ("rbp 1/16", || Box::new(Rbp::new(1.0 / 16.0))),
        ("lbp", || Box::new(Lbp::new())),
    ];
    for (label, mk) in scheds {
        for mode in [ResidualRefresh::Exact, ResidualRefresh::Lazy] {
            let what = format!("{label}/{mode:?}");
            let p = RunParams { eps: 1e-4, ..params(mode) };
            let mut warm = SessionBuilder::new(g.clone(), mk_engine("native"), mk())
                .with_params(p.clone())
                .build()
                .unwrap();
            warm.solve().unwrap();
            warm.apply_evidence(&[(flip_vertex, &[0.6, -0.6])]).unwrap();
            let (warm_rows, warm_iters, warm_ok) = {
                let r = warm.solve().unwrap();
                (r.update_rows(), r.iterations, r.converged())
            };
            assert!(warm_ok, "{what}: warm re-solve did not converge");
            assert!(warm_iters > 0, "{what}: the flip must cost real work");
            let cold = {
                let mut eng = mk_engine("native");
                let mut s = mk();
                run(warm.graph(), eng.as_mut(), s.as_mut(), &p).unwrap()
            };
            assert!(cold.converged(), "{what}: cold reference did not converge");
            assert!(
                warm_rows < cold.update_rows(),
                "{what}: warm {} rows vs cold {} — warm start saved nothing",
                warm_rows,
                cold.update_rows()
            );
            // iterations: non-strict — a sync sweep count is decay-
            // driven for warm and cold alike; rows (above) carry the
            // strict acceptance bar
            assert!(
                warm_iters <= cold.iterations,
                "{what}: warm {} iterations vs cold {}",
                warm_iters,
                cold.iterations
            );
        }
    }
}

#[test]
fn clear_evidence_restores_base_graph_bitwise() {
    let mut rng = Rng::new(99);
    let (_, g) = random_mrf(&mut rng);
    let base = g.log_unary.clone();
    let base_id = g.instance_id;
    let mut session = SessionBuilder::new(g, mk_engine("native"), mk_sched("lbp"))
        .with_params(params(ResidualRefresh::Exact))
        .build()
        .unwrap();
    session.solve().unwrap();
    let clean = session.marginals().unwrap();
    let mut stream = EvidenceStream::new(4, 2, 1.0);
    let batch = stream.next_batch(session.graph());
    apply(&mut session, &batch);
    assert!(!session.evidence_vertices().is_empty());
    assert_ne!(
        session.graph().instance_id,
        base_id,
        "evidence must re-allocate the instance id (engines cache by it)"
    );
    session.solve().unwrap();
    session.clear_evidence().unwrap();
    assert_eq!(session.graph().log_unary, base, "unaries must restore bitwise");
    assert!(session.evidence_vertices().is_empty());
    let r = session.solve().unwrap();
    assert!(r.converged());
    let restored = session.marginals().unwrap();
    for (i, (x, y)) in clean.iter().zip(&restored).enumerate() {
        assert!(
            (x - y).abs() < 1e-3,
            "marginal[{i}] clean {x} vs restored {y}"
        );
    }
}

#[test]
fn shim_run_is_bit_identical_to_session_solve() {
    let mut rng = Rng::new(123);
    let (glabel, g) = random_mrf(&mut rng);
    for sched in ["lbp", "rbp", "rs", "rnbp"] {
        for engine in engines_under_test() {
            let what = format!("{glabel}/{sched}/{engine}");
            let p = params(ResidualRefresh::Exact);
            let shim: RunResult = {
                let mut eng = mk_engine(engine);
                let mut s = mk_sched(sched);
                run(&g, eng.as_mut(), s.as_mut(), &p).unwrap()
            };
            let mut session = SessionBuilder::new(g.clone(), mk_engine(engine), mk_sched(sched))
                .with_params(p)
                .build()
                .unwrap();
            let r = session.solve().unwrap();
            assert_eq!(shim.stop, r.stop, "{what}");
            assert_eq!(shim.iterations, r.iterations, "{what}");
            assert_eq!(shim.message_updates, r.message_updates, "{what}");
            assert_eq!(shim.engine_calls, r.engine_calls, "{what}");
            assert_eq!(shim.refresh_rows, r.refresh_rows, "{what}");
            assert_eq!(shim.frontier_digest, r.frontier_digest, "{what}");
            assert_bits_equal(
                shim.marginals.as_ref().unwrap(),
                r.marginals.as_ref().unwrap(),
                &format!("{what}: marginals"),
            );
        }
    }
}

#[test]
fn evidence_validation_is_atomic_and_borrowed_sessions_refuse() {
    let mut rng = Rng::new(321);
    let (_, g) = random_mrf(&mut rng);
    let mut session = SessionBuilder::new(g.clone(), mk_engine("native"), mk_sched("lbp"))
        .with_params(params(ResidualRefresh::Exact))
        .build()
        .unwrap();
    session.solve().unwrap();
    let before = session.graph().log_unary.clone();
    let good: Vec<f32> = vec![0.25; session.graph().arity_of(0)];
    let bad = vec![f32::NAN; session.graph().arity_of(1)];
    assert!(session
        .apply_evidence(&[(0, good.as_slice()), (1, bad.as_slice())])
        .is_err());
    assert_eq!(
        session.graph().log_unary,
        before,
        "a rejected batch must leave the graph untouched"
    );
    assert!(session.apply_evidence(&[(usize::MAX, good.as_slice())]).is_err());
    assert!(session.apply_evidence(&[(0, &[] as &[f32])]).is_err());

    // borrowed (shim-style) sessions share the graph: no evidence
    let mut eng = NativeEngine::new();
    let mut s = Lbp::new();
    let mut borrowed = Session::over(&g, &mut eng, &mut s, params(ResidualRefresh::Exact));
    borrowed.solve().unwrap();
    assert!(borrowed.apply_evidence(&[(0, good.as_slice())]).is_err());
    assert!(borrowed.clear_evidence().is_err());
}
